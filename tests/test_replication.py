"""Replicated shard execution: hedged scatter, read failover, and
replica catch-up (ISSUE 8 / docs/replication.md).

Acceptance contract: with ``replicas=2`` per shard, killing any single
member mid-flight leaves every parity query **byte-identical** to the
in-process sharded oracle with ``degraded_shards == 0`` — reads fail
over to a live synced replica instead of opening the directory
read-only.  Byte-identity across members is possible because
:meth:`ReplicaSet.sync` ships the primary's segments in order plus its
WAL tail, so a synced replica holds the exact ``(sealed, buffer,
seq)`` version and runs the same deterministic partial/merge algebra
over the same segment sequence.
"""

import json
import threading
import time

import pytest

from conftest import random_records, random_store
from test_incremental import rows_identical

from repro.core import remote as rm
from repro.core import segmentio
from repro.core.remote import RemoteShardedAggregator
from repro.core.schema import MetricRecord
from repro.core.splunklite import query

SEAL = 53
IDLE_S = 300.0  # workers self-exit if a wedged run leaks them
RECORDS = random_records(seed=5, n=420)

FLEET_Q = ("search kind=perf gflops>10 | stats avg(gflops) p90(gflops) "
           "count by job | sort -avg_gflops | head 10")

SWEEP = [FLEET_Q,
         "stats stdev(gflops) range(gflops) dc(host) dc(app) by kind",
         "stats median(gflops) p25(gflops) p90(gflops) by job",
         "search kind=perf | stats first(app) last(gflops)",  # exact gather
         "search kind=perf | sort -gflops | head 7",
         "dedup job app"]


def make_replicated(directory, n, replicas=2, records=RECORDS, **kw):
    agg = RemoteShardedAggregator(num_shards=n, directory=directory,
                                  seal_threshold=SEAL, replicas=replicas,
                                  worker_idle_timeout_s=IDLE_S,
                                  spawn_timeout_s=60.0, **kw)
    for rec in records:
        agg.insert(rec)
    return agg


@pytest.fixture()
def rep_pair(tmp_path):
    inproc = random_store(records=RECORDS, shards=2, seal_threshold=SEAL)
    agg = make_replicated(tmp_path / "fleet", 2)
    agg.sync_replicas()
    yield inproc, agg
    agg.close()
    inproc.close()


# ===========================================================================
# Sync: replicas converge to the primary's exact version
# ===========================================================================

def test_sync_converges_member_versions(rep_pair):
    _inproc, agg = rep_pair
    for sh in agg.shards:
        versions = {tuple(m._version()) for m in sh.members}
        assert len(versions) == 1, f"shard {sh.index} diverged: {versions}"
    rs = agg.replication_stats()
    assert rs["replica_sets"] == 2 and rs["replicas"] == 2
    assert rs["synced_members"] == rs["members"] == 4
    assert rs["stale_sets"] == 0 and rs["syncs"] == 2


def test_sync_is_incremental_after_new_data(rep_pair):
    """A second sync ships only the delta: segments sealed since the
    last sync plus the WAL tail — never a full reset."""
    _inproc, agg = rep_pair
    for i in range(SEAL + 10):  # one new sealed segment + buffer tail
        agg.insert(MetricRecord(90000.0 + i, "n0", "delta.1", "perf",
                                {"gflops": float(i)}))
    before = [tuple(sh.primary._version()) for sh in agg.shards]
    stats = agg.sync_replicas()
    assert all(s["resets"] == 0 for s in stats)
    assert sum(s["segments_shipped"] for s in stats) >= 1
    for sh, v in zip(agg.shards, before):
        assert tuple(sh.members[1]._version()) == v


def test_writes_mark_set_stale_until_next_sync(rep_pair):
    """Write-path invariant: writes land on the primary only, and any
    write pins subsequent reads to the primary until a sync proves the
    replicas caught up (a replica behind the primary's WAL must never
    answer)."""
    _inproc, agg = rep_pair
    sh = agg.shards[0]
    assert not sh.stale
    agg.insert(MetricRecord(91000.0, "n1", "stale.1", "perf",
                            {"gflops": 1.0}))
    assert agg.shards[agg.shard_index(
        MetricRecord(91000.0, "n1", "stale.1", "perf", {}))].stale
    stale_set = next(s for s in agg.shards if s.stale)
    assert stale_set._read_order() == [stale_set.primary]
    agg.sync_replicas()
    assert not stale_set.stale
    assert len(stale_set._read_order()) == 2


# ===========================================================================
# Failover: any single member dies, parity holds, no degraded mode
# ===========================================================================

def test_replica_killed_parity_sweep(rep_pair):
    inproc, agg = rep_pair
    want = {q: query(inproc, q) for q in SWEEP}
    agg.kill_worker(0, member=1)
    agg.kill_worker(1, member=1)
    for q in SWEEP:
        rows_identical(query(agg, q), want[q], q)
        assert agg.last_query_stats["degraded_shards"] == 0, q


def test_primary_killed_fails_over_to_replica(rep_pair):
    inproc, agg = rep_pair
    want = {q: query(inproc, q) for q in SWEEP}
    query(agg, FLEET_Q)  # measure latencies: primaries become preferred
    agg.kill_worker(0, member=0)
    agg.kill_worker(1, member=0)
    for q in SWEEP:
        rows_identical(query(agg, q), want[q], q)
        assert agg.last_query_stats["degraded_shards"] == 0, q
    rs = agg.replication_stats()
    assert rs["failovers"] > 0
    assert rs["degraded_calls"] == 0
    # the store surface fails over too (dashboards keep rendering)
    assert agg.jobs() == inproc.jobs()
    assert len(agg) == len(inproc)


def test_all_members_dead_degrades_to_primary_dir(rep_pair):
    """Only when *every* member is gone does the set degrade — and to
    the primary's directory, whose WAL is at least as fresh as any
    replica's state."""
    inproc, agg = rep_pair
    want = query(inproc, FLEET_Q)
    for member in (0, 1):
        agg.kill_worker(0, member=member)
        agg.kill_worker(1, member=member)
    rows_identical(query(agg, FLEET_Q), want, FLEET_Q)
    assert agg.last_query_stats["degraded_shards"] == 2
    assert agg.replication_stats()["degraded_calls"] >= 2


def test_stale_set_with_dead_primary_degrades_not_lies(rep_pair):
    """A stale set whose primary dies must not fail over to a replica
    missing the staleing write: it degrades to the primary's durable
    directory (WAL included) and still returns the full answer."""
    inproc, agg = rep_pair
    i = 0
    while not all(sh.stale for sh in agg.shards):  # stale every set
        extra = MetricRecord(92000.0 + i, f"n{i}", "alpha.1", "perf",
                             {"gflops": 999.0 + i})
        assert agg.insert(extra) and inproc.insert(extra)
        i += 1
    query(inproc, FLEET_Q)
    agg.kill_worker(0, member=0)
    agg.kill_worker(1, member=0)
    rows_identical(query(agg, FLEET_Q), query(inproc, FLEET_Q), FLEET_Q)
    assert agg.last_query_stats["degraded_shards"] == 2


# ===========================================================================
# Catch-up: a restarted replica converges via segments + WAL tail
# ===========================================================================

def test_restarted_replica_catches_up_and_serves(rep_pair):
    inproc, agg = rep_pair
    agg.restart_worker(0, member=1)
    assert not agg.shards[0]._synced[1]  # out of the read set until sync
    for i in range(40):  # move the primary past the replica
        rec = MetricRecord(93000.0 + i, "n0", "catch.1", "perf",
                           {"gflops": float(i)})
        agg.insert(rec)
        inproc.insert(rec)
    stats = agg.sync_replicas()
    assert all(s["synced"] == 1 for s in stats)
    for sh in agg.shards:
        assert tuple(sh.members[1]._version()) == \
            tuple(sh.primary._version())
    # the caught-up replica actually serves: kill both primaries
    query(agg, FLEET_Q)
    agg.kill_worker(0, member=0)
    agg.kill_worker(1, member=0)
    for q in SWEEP:
        rows_identical(query(agg, q), query(inproc, q), q)
        assert agg.last_query_stats["degraded_shards"] == 0, q


def test_compaction_divergence_forces_full_reset(rep_pair):
    """Compaction rewrites the primary's committed history, so the
    replica's segment list stops being a prefix — sync detects it and
    re-adopts from scratch, converging anyway."""
    _inproc, agg = rep_pair
    agg.compact_all(small_rows=10 ** 9, target_rows=10 ** 9)
    stats = agg.sync_replicas()
    assert sum(s["resets"] for s in stats) == 2
    for sh in agg.shards:
        assert tuple(sh.members[1]._version()) == \
            tuple(sh.primary._version())


def test_sync_tolerates_dead_members(rep_pair):
    _inproc, agg = rep_pair
    agg.kill_worker(0, member=1)
    stats = agg.sync_replicas()
    assert stats[0]["unreachable"] == 1 and stats[1]["unreachable"] == 0
    agg.kill_worker(1, member=0)
    stats = agg.sync_replicas()
    assert stats[1].get("primary_unreachable") is True


# ===========================================================================
# Hedging: a slow member is raced, the fast reply wins
# ===========================================================================

def test_hedged_scatter_beats_slow_member(tmp_path):
    inproc = random_store(records=RECORDS, shards=2, seal_threshold=SEAL)
    agg = make_replicated(tmp_path / "fleet", 2, hedge_delay_s=0.02)
    try:
        agg.sync_replicas()
        want = query(inproc, FLEET_Q)
        rows_identical(query(agg, FLEET_Q), want, FLEET_Q)
        sh = agg.shards[0]
        slow = sh._read_order()[0]  # whoever is preferred right now
        slow.rpc("set_delay", s=0.4)
        agg.drop_scatter_memos()  # force a real scatter, not not_modified
        t0 = time.monotonic()
        rows_identical(query(agg, FLEET_Q), want, FLEET_Q)
        elapsed = time.monotonic() - t0
        stats = agg.last_query_stats
        assert stats["hedged_shards"] >= 1
        assert stats["degraded_shards"] == 0
        assert elapsed < 0.4  # the hedge won without waiting out the delay
        rs = sh.replication_stats()
        assert rs["hedged_ops"] >= 1 and rs["hedge_wins"] >= 1
    finally:
        agg.close()
        inproc.close()


def test_member_killed_mid_scatter_hedged_reply_identical(tmp_path):
    """Kill the preferred member *while its scatter is in flight*: the
    hedge fires, the survivor's reply is byte-identical to the oracle,
    and the dead loser is cancelled — never surfaced as degraded."""
    inproc = random_store(records=RECORDS, shards=2, seal_threshold=SEAL)
    agg = make_replicated(tmp_path / "fleet", 2, hedge_delay_s=0.02)
    try:
        agg.sync_replicas()
        want = {q: query(inproc, q) for q in SWEEP}
        sh = agg.shards[0]
        slow = sh._read_order()[0]
        slow.rpc("set_delay", s=0.5)
        agg.drop_scatter_memos()
        member = sh.members.index(slow)
        timer = threading.Timer(0.1, lambda: agg.kill_worker(0,
                                                             member=member))
        timer.start()
        try:
            rows_identical(query(agg, FLEET_Q), want[FLEET_Q], FLEET_Q)
        finally:
            timer.join()
        assert agg.last_query_stats["degraded_shards"] == 0
        for q in SWEEP:  # the whole sweep stays identical afterwards
            rows_identical(query(agg, q), want[q], q)
            assert agg.last_query_stats["degraded_shards"] == 0, q
    finally:
        agg.close()
        inproc.close()


def test_hedging_disabled_never_hedges(tmp_path):
    agg = make_replicated(tmp_path / "fleet", 2, records=RECORDS[:80],
                          hedge=False, hedge_delay_s=0.0)
    try:
        agg.sync_replicas()
        agg.shards[0]._read_order()[0].rpc("set_delay", s=0.1)
        query(agg, FLEET_Q)
        assert agg.last_query_stats["hedged_shards"] == 0
        assert agg.replication_stats()["hedged_ops"] == 0
    finally:
        agg.close()


# ===========================================================================
# Manifest, stats surfaces, constructor contracts
# ===========================================================================

def test_manifest_replication_block_and_epoch_bump(tmp_path):
    agg = make_replicated(tmp_path / "fleet", 2, records=RECORDS[:60])
    try:
        man = json.loads((tmp_path / "fleet" / "shards.json").read_text())
        rep = man["replication"]
        assert rep["k"] == 2
        epoch0 = rep["epoch"]
        assert epoch0 >= 1
        assert len(rep["members"]) == 4  # 2 shards x 2 members
        dirs = {m["dir"] for m in rep["members"]}
        assert dirs == {"shard-00", "shard-00.r1",
                        "shard-01", "shard-01.r1"}
        agg.restart_worker(0, member=1)  # membership change: epoch bumps
        man = json.loads((tmp_path / "fleet" / "shards.json").read_text())
        assert man["replication"]["epoch"] > epoch0
        # routing keys stay protected
        with pytest.raises(ValueError):
            segmentio.update_shardset_manifest(tmp_path / "fleet",
                                               {"num_shards": 7})
    finally:
        agg.close()


def test_explain_and_service_stats_surface_replication(rep_pair):
    from repro.core.service import QueryService
    inproc, agg = rep_pair
    ex = agg.explain(FLEET_Q)
    assert ex["replication"]["replica_sets"] == 2
    assert all(w["replicas_alive"] == [True, True] for w in ex["workers"])
    with QueryService(agg) as svc:
        rows_identical(svc.query(FLEET_Q), query(inproc, FLEET_Q),
                       FLEET_Q)
        st = svc.stats()
        assert st["replication"]["members"] == 4
    # close_store=False default: the fleet survives the service
    assert all(agg.workers_alive())


def test_unreplicated_fleet_reports_no_replication(tmp_path):
    agg = RemoteShardedAggregator(num_shards=2, directory=tmp_path / "f",
                                  seal_threshold=SEAL,
                                  worker_idle_timeout_s=IDLE_S)
    try:
        for rec in RECORDS[:40]:
            agg.insert(rec)
        assert agg.replication_stats() is None
        _rows, stats = agg.query_with_stats(FLEET_Q)
        assert stats["hedged_shards"] == 0
        assert stats["failover_shards"] == 0
        assert "replication" not in agg.explain(FLEET_Q)
        assert agg.sync_replicas() == [
            {"replicas": 0, "synced": 0, "segments_shipped": 0,
             "resets": 0, "unreachable": 0}] * 2
    finally:
        agg.close()


def test_replication_constructor_contracts(tmp_path):
    with pytest.raises(ValueError, match="replicas"):
        RemoteShardedAggregator(num_shards=1, directory=tmp_path / "f",
                                replicas=0)
    with pytest.raises(ValueError, match="spawned"):
        RemoteShardedAggregator(num_shards=1, directory=tmp_path / "f",
                                replicas=2, addresses=[("127.0.0.1", 1)])
    from repro.core.aggregator import Aggregator
    with pytest.raises(ValueError, match="remote_workers"):
        Aggregator(tmp_path / "inbox", shards=2, replicas=2,
                   store_dir=tmp_path / "f")


def test_aggregator_passes_replication_kwargs(tmp_path):
    from repro.core.aggregator import Aggregator
    agg = Aggregator(tmp_path / "inbox", shards=1, remote_workers=True,
                     replicas=2, hedge_delay_s=0.01,
                     store_dir=tmp_path / "fleet")
    try:
        assert isinstance(agg.store, RemoteShardedAggregator)
        assert agg.store._replicas == 2
        assert agg.store.shards[0].hedge_delay_s == 0.01
        assert agg.store.shards[0].is_replicated
    finally:
        agg.close()


def test_stale_replica_reply_is_discarded(rep_pair):
    """Version guard: a non-primary reply at a version other than the
    synced one is never served — it is counted and the op retries on
    another member."""
    _inproc, agg = rep_pair
    sh = agg.shards[0]
    # sabotage: pretend the set synced at a version nobody is at
    with sh._lock:
        sh._synced_version = (999, 999, 999)
    query(agg, FLEET_Q)  # primary replies are exempt from the guard
    assert agg.last_query_stats["degraded_shards"] == 0
