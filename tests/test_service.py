"""Multi-tenant query service: concurrency parity, admission control,
in-flight dedup, result caching, fairness, and backpressure.

Acceptance contract (ISSUE 7 / docs/service.md): 8 threads issuing the
full parity sweep through a :class:`QueryService` — over a single
store, an in-process shard set, and a remote worker fleet — get rows
**byte-identical** to a serial direct-path run, with ingest pumped
between rounds; K identical concurrent submissions execute exactly
once; per-tenant quotas, interactive-over-batch fairness and
shed-under-backpressure behave as documented; and concurrent callers
never see each other's stats (the re-entrancy satellite).
"""

import threading
import time

import pytest

from conftest import random_records
from test_engine_parity import AGG_QUERIES, PIPELINE_QUERIES, SEARCH_QUERIES
from test_incremental import rows_identical

import repro.core.service as service_mod
from repro.core.aggregator import Aggregator
from repro.core.schema import MetricRecord, encode_line
from repro.core.service import QueryService, QuotaExceeded
from repro.core.splunklite import QueryHandle, query, query_with_stats

ALL_QUERIES = SEARCH_QUERIES + AGG_QUERIES + PIPELINE_QUERIES
N_THREADS = 8
IDLE_S = 300.0


def _record_batches(rounds=3, per_round=150):
    recs = random_records(seed=11, n=rounds * per_round)
    return [recs[i * per_round:(i + 1) * per_round] for i in range(rounds)]


def _make_agg(tmp_path, shape):
    if shape == "single":
        return Aggregator(tmp_path / "inbox", store_dir=tmp_path / "store")
    if shape == "sharded":
        return Aggregator(tmp_path / "inbox", store_dir=tmp_path / "store",
                          shards=3)
    from repro.core.remote import RemoteShardedAggregator
    store = RemoteShardedAggregator(num_shards=2,
                                    directory=tmp_path / "store",
                                    seal_threshold=53,
                                    worker_idle_timeout_s=IDLE_S)
    return Aggregator(tmp_path / "inbox", store=store)


def _pump_round(agg, recs, round_no):
    inbox = agg.inbox_dir / "stream.log"
    with open(inbox, "a", encoding="utf-8") as f:
        for rec in recs:
            f.write(encode_line(rec) + "\n")
    assert agg.pump() == len(recs)


def _sweep_concurrently(svc, serial, n_threads=N_THREADS):
    """Every thread runs the whole sweep; byte-identical per call."""
    failures = []

    def run(tid):
        try:
            for q in ALL_QUERIES:
                rows, stats = svc.query_with_stats(q, tenant=f"t{tid}")
                assert isinstance(stats, dict) and stats, \
                    f"{q!r}: stats missing"
                rows_identical(rows, serial[q], q)
        except BaseException as exc:  # pragma: no cover - diagnostics
            failures.append((tid, exc))

    threads = [threading.Thread(target=run, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not failures, failures[0]


# ===========================================================================
# Tentpole: N-thread parity sweep with interleaved ingest, all 3 shapes
# ===========================================================================

@pytest.mark.parametrize("shape", ["single", "sharded", "remote"])
def test_concurrent_sweep_parity(tmp_path, shape):
    agg = _make_agg(tmp_path, shape)
    try:
        svc = QueryService(agg.store, max_concurrency=4,
                           tenant_quota=0)  # sweep threads run unthrottled
        with svc:
            for rnd, recs in enumerate(_record_batches()):
                _pump_round(agg, recs, rnd)
                # quiesced store: the serial direct path is the oracle
                serial = {q: query(agg.store, q) for q in ALL_QUERIES}
                _sweep_concurrently(svc, serial)
            st = svc.stats()
            # the sweep repeats identical plans 8x per round: the
            # service must have collapsed most of that repetition
            assert st["result_cache_hits"] + st["deduped"] > 0
            assert st["executed"] < st["submitted"]
    finally:
        agg.close()


def test_concurrent_queries_during_ingest(tmp_path):
    """True-concurrency smoke: readers race a live writer thread.

    Byte-identical parity is only defined on a quiesced store, so this
    asserts no errors/cross-talk while racing and exact parity after
    the writer finishes."""
    agg = _make_agg(tmp_path, "sharded")
    try:
        recs = random_records(seed=23, n=600)
        stop = threading.Event()
        failures = []

        def writer():
            try:
                for rec in recs:
                    agg.store.insert(rec)
            finally:
                stop.set()

        def reader(tid):
            try:
                while not stop.is_set():
                    for q in ALL_QUERIES[::4]:
                        rows, stats = query_with_stats(agg.store, q)
                        assert isinstance(rows, list)
                        assert isinstance(stats, dict)
            except BaseException as exc:  # pragma: no cover
                failures.append((tid, exc))

        threads = [threading.Thread(target=reader, args=(i,))
                   for i in range(4)]
        wt = threading.Thread(target=writer)
        for t in threads:
            t.start()
        wt.start()
        wt.join()
        for t in threads:
            t.join()
        assert not failures, failures[0]
        with QueryService(agg.store) as svc:
            for q in ALL_QUERIES[::4]:
                rows_identical(svc.query(q), query(agg.store, q), q)
    finally:
        agg.close()


# ===========================================================================
# Satellite: stats travel with the call — no cross-talk between threads
# ===========================================================================

def test_no_stats_cross_talk(tmp_path):
    agg = _make_agg(tmp_path, "single")
    try:
        for rec in random_records(seed=7, n=300):
            agg.store.insert(rec)
        q = "search kind=perf | stats avg(gflops) by job | sort job"
        want = {"rows": "rows", "incremental": "incremental",
                None: "full"}
        failures = []

        def run(engine):
            try:
                for _ in range(30):
                    _rows, stats = query_with_stats(agg.store, q,
                                                    engine=engine)
                    assert stats["mode"] == want[engine], \
                        f"engine {engine!r} saw {stats['mode']!r}"
            except BaseException as exc:  # pragma: no cover
                failures.append(exc)

        threads = [threading.Thread(target=run, args=(e,))
                   for e in ("rows", "incremental", None) for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not failures, failures[0]
    finally:
        agg.close()


# ===========================================================================
# In-flight dedup, quotas, fairness, backpressure (gated executor)
# ===========================================================================

@pytest.fixture()
def gated(monkeypatch):
    """Pause every service execution until its per-query gate opens.

    Returns ``(gate_for, started, calls)``: ``gate_for(q).set()``
    releases executions of ``q``; ``started[q]`` is set once one is
    running; ``calls`` counts executions per query string."""
    real = service_mod._direct_query_with_stats
    gates, started, calls = {}, {}, {}
    lock = threading.Lock()

    def gate_for(q):
        with lock:
            return gates.setdefault(q, threading.Event())

    def started_for(q):
        with lock:
            return started.setdefault(q, threading.Event())

    def slow(store, q, **kw):
        with lock:
            calls[q] = calls.get(q, 0) + 1
        started_for(q).set()
        assert gate_for(q).wait(10), f"gate for {q!r} never opened"
        return real(store, q, **kw)

    monkeypatch.setattr(service_mod, "_direct_query_with_stats", slow)
    return gate_for, started_for, calls


@pytest.fixture()
def small_store():
    from repro.core.aggregator import MetricStore
    store = MetricStore(seal_threshold=64)
    for rec in random_records(seed=3, n=200):
        store.insert(rec)
    return store


DEDUP_Q = "search kind=perf | stats avg(gflops) count by job | sort job"


def test_inflight_dedup_k_to_one(small_store, gated):
    gate_for, _started, calls = gated
    with QueryService(small_store, max_concurrency=4,
                      result_cache_size=0) as svc:
        tickets = [svc.submit(DEDUP_Q, tenant=f"t{i}") for i in range(8)]
        gate_for(DEDUP_Q).set()
        results = [t.result(timeout=10) for t in tickets]
        assert calls[DEDUP_Q] == 1  # K submissions, one execution
        assert svc.counters["executed"] == 1
        assert svc.counters["deduped"] == 7
        first = results[0].rows
        assert all(r.rows == first for r in results)
        assert sorted(r.source for r in results) == \
            ["deduped"] * 7 + ["executed"]


def test_tenant_quota(small_store, gated):
    gate_for, _started, _calls = gated
    q2 = "stats count by job | sort job"
    with QueryService(small_store, max_concurrency=1,
                      tenant_quota=2, result_cache_size=0) as svc:
        t1 = svc.submit(DEDUP_Q, tenant="greedy")
        t2 = svc.submit(q2, tenant="greedy")
        with pytest.raises(QuotaExceeded):
            svc.submit("stats count", tenant="greedy")
        # other tenants are unaffected by greedy's backlog
        t3 = svc.submit(DEDUP_Q, tenant="polite")
        assert svc.counters["quota_rejections"] == 1
        for q in (DEDUP_Q, q2, "stats count"):
            gate_for(q).set()
        for t in (t1, t2, t3):
            t.result(timeout=10)
        # quota is on *outstanding* work: it frees up on completion
        svc.submit("stats count", tenant="greedy").result(timeout=10)


def test_batch_never_starves_interactive(small_store, gated):
    gate_for, started_for, _calls = gated
    b1, b2 = "stats count by job | sort job", "stats count by host | sort host"
    i1 = "stats count"
    with QueryService(small_store, max_concurrency=2,
                      result_cache_size=0) as svc:
        assert svc.batch_slots == 1
        tb1 = svc.submit(b1, priority="batch")
        assert started_for(b1).wait(5)
        tb2 = svc.submit(b2, priority="batch")   # queued: batch slot held
        ti = svc.submit(i1)                      # interactive jumps it
        assert started_for(i1).wait(5)
        assert not started_for(b2).is_set()      # b2 still waiting
        for q in (b1, b2, i1):
            gate_for(q).set()
        for t in (tb1, tb2, ti):
            t.result(timeout=10)


def test_backpressure_shed_and_delay(small_store, gated):
    gate_for, started_for, _calls = gated
    q1, q2, q3 = "stats count", "stats count by job", "stats count by host"
    with QueryService(small_store, max_concurrency=1, queue_limit=1,
                      result_cache_size=0) as svc:
        t1 = svc.submit(q1)
        assert started_for(q1).wait(5)
        t2 = svc.submit(q2)              # fills the queue
        shed = svc.submit(q3, shed_ok=True)
        res = shed.result()
        assert res.source == "shed" and res.rows is None \
            and res.stats == {"shed": True}
        assert svc.counters["shed"] == 1

        delayed = []

        def blocked_submit():
            delayed.append(svc.submit(q3).result(timeout=10))

        th = threading.Thread(target=blocked_submit)
        th.start()
        time.sleep(0.1)
        assert not delayed               # still delayed behind the queue
        for q in (q1, q2, q3):
            gate_for(q).set()
        th.join(timeout=10)
        assert delayed and delayed[0].rows is not None
        t1.result(timeout=10), t2.result(timeout=10)


# ===========================================================================
# Shared result cache: version-keyed, bounded
# ===========================================================================

def test_result_cache_version_keying(small_store):
    with QueryService(small_store, result_cache_size=8) as svc:
        first = svc.query(DEDUP_Q)
        assert svc.counters["result_cache_hits"] == 0
        again = svc.query(DEDUP_Q)
        assert svc.counters["result_cache_hits"] == 1
        assert again == first
        # any ingest moves the store version: the entry is dead
        small_store.insert(MetricRecord(ts=9999.0, host="n0", job="alpha.1",
                                        kind="perf",
                                        fields={"gflops": 123.0}))
        refreshed = svc.query(DEDUP_Q)
        assert svc.counters["result_cache_hits"] == 1
        assert svc.counters["executed"] == 2
        rows_identical(refreshed, query(small_store, DEDUP_Q), DEDUP_Q)


def test_result_cache_bounded(small_store):
    with QueryService(small_store, result_cache_size=2) as svc:
        for q in ("stats count", "stats count by job",
                  "stats count by host"):
            svc.query(q)
        assert svc.stats()["result_cache_entries"] <= 2


def test_dedup_key_includes_tail_and_engine(small_store):
    """Plans sharing a fingerprint but differing in tail/engine must
    not collide in the cache (byte-identical invariant)."""
    shared_prefix = "search kind=perf | stats avg(gflops) by job"
    with QueryService(small_store) as svc:
        a = svc.query(shared_prefix + " | sort job")
        b = svc.query(shared_prefix + " | sort -avg_gflops | head 2")
        rows_identical(a, query(small_store, shared_prefix + " | sort job"),
                       "tail a")
        rows_identical(
            b, query(small_store,
                     shared_prefix + " | sort -avg_gflops | head 2"),
            "tail b")
        c = svc.query(shared_prefix + " | sort job", engine="rows")
        rows_identical(
            c, query(small_store, shared_prefix + " | sort job",
                     engine="rows"), "rows engine")


# ===========================================================================
# Watch lifecycle: close / unwatch / service routing (satellite)
# ===========================================================================

def test_unwatch_and_closed_handles(tmp_path):
    agg = Aggregator(tmp_path / "inbox")
    for rec in random_records(seed=9, n=120):
        agg.store.insert(rec)
    h1 = agg.watch("stats count by job | sort job")
    h2 = agg.watch("stats count")
    assert len(agg.watches) == 2
    h1.refresh()
    assert agg.unwatch(h1) and not agg.unwatch(h1)  # idempotent
    assert agg.watches == [h2]
    with pytest.raises(RuntimeError):
        h1.refresh()
    h2.close()  # closing without unwatch: refresh_watches reaps it
    assert agg.refresh_watches() == {}
    assert agg.watches == []


def test_watch_routes_through_service(tmp_path):
    agg = Aggregator(tmp_path / "inbox", query_service=True)
    try:
        for rec in random_records(seed=13, n=150):
            agg.store.insert(rec)
        q = "search kind=perf | stats avg(gflops) by job | sort job"
        h = agg.watch(q)
        assert h.service is agg.query_service and h.shed_ok
        rows_identical(h.refresh(), query(agg.store, q), q)
        assert agg.query_service.counters["executed"] == 1
        # unchanged store: the handle's own version check short-circuits
        h.refresh()
        assert agg.query_service.counters["executed"] == 1
    finally:
        agg.close()


def test_handle_returns_stale_rows_when_shed(small_store, gated):
    gate_for, started_for, _calls = gated
    blocker, filler = "stats count by host", "stats count"
    watched = "stats count by job | sort job"
    svc = QueryService(small_store, max_concurrency=1, queue_limit=1,
                       result_cache_size=0)
    with svc:
        h = QueryHandle(small_store, watched, service=svc, shed_ok=True)
        gate_for(watched).set()
        first = h.refresh()
        # saturate: one flight executing, one queued — the full queue
        # sheds every further shed_ok submission
        tb = svc.submit(blocker)
        assert started_for(blocker).wait(5)
        tf = svc.submit(filler)
        small_store.insert(MetricRecord(ts=9999.0, host="n9", job="beta.2",
                                        kind="perf",
                                        fields={"gflops": 1.0}))
        assert h.refresh() is first          # shed → stale rows, no wait
        assert svc.counters["shed"] == 1
        for q in (blocker, filler):
            gate_for(q).set()
        tb.result(timeout=10), tf.result(timeout=10)
        refreshed = h.refresh()              # quiet again: catches up
        rows_identical(refreshed, query(small_store, watched), watched)
