"""Daemon behaviour: clock alignment, suspend, idle-node policy, one-shot
sources."""

from repro.core.daemon import DaemonConfig, Hpcmd, JobManifest
from repro.core.schema import parse_line
from repro.core.sources import MetricSource


class DummySource(MetricSource):
    name = "dummy"
    kind = "perf"

    def __init__(self):
        self.calls = 0

    def collect(self, now):
        self.calls += 1
        return {"v": self.calls}


class OneShot(MetricSource):
    name = "meta"
    kind = "meta"
    once = True

    def collect(self, now):
        return {"hello": 1}


class Exploding(MetricSource):
    name = "boom"
    kind = "perf"

    def collect(self, now):
        raise RuntimeError("sensor failure")


def mk(tmp_path, manifest=True, **cfg):
    d = Hpcmd(tmp_path / "spool",
              DaemonConfig(align_to_clock=False, interval_s=1.0, **cfg),
              host="n0",
              manifest=JobManifest(job_id="j1") if manifest else None)
    return d


def read_records(tmp_path):
    recs = []
    for seg in sorted((tmp_path / "spool").glob("segment-*.log")):
        for line in seg.read_text().splitlines():
            rec = parse_line(line)
            if rec:
                recs.append(rec)
    return recs


def test_tick_writes_records(tmp_path):
    d = mk(tmp_path)
    d.add_source(DummySource())
    assert d.tick(100.0) == 1
    assert d.tick(101.0) == 1
    recs = read_records(tmp_path)
    assert len(recs) == 2 and recs[0].job == "j1"


def test_idle_node_not_monitored(tmp_path):
    d = mk(tmp_path, manifest=False)
    d.add_source(DummySource())
    assert d.node_state == "idle"
    assert d.tick(100.0) == 0
    d.set_manifest(JobManifest(job_id="j2"))
    assert d.tick(101.0) == 1
    assert read_records(tmp_path)[0].job == "j2"


def test_suspend_resume(tmp_path):
    d = mk(tmp_path)
    src = DummySource()
    d.add_source(src)
    with d.suspended():
        assert d.tick(100.0) == 0
    assert d.tick(101.0) == 1
    assert src.calls == 1


def test_once_source_emits_once_per_job(tmp_path):
    d = mk(tmp_path)
    d.add_source(OneShot())
    assert d.tick(1.0) == 1
    assert d.tick(2.0) == 0
    d.set_manifest(JobManifest(job_id="j2"))  # new job -> re-emit
    assert d.tick(3.0) == 1


def test_source_errors_are_contained(tmp_path):
    d = mk(tmp_path)
    d.add_source(Exploding())
    d.add_source(DummySource())
    assert d.tick(1.0) == 2  # error record + real record
    recs = read_records(tmp_path)
    assert any("source_error" in r.fields for r in recs)


def test_clock_alignment():
    d = Hpcmd("/tmp/unused-spool-align",
              DaemonConfig(align_to_clock=True, interval_s=600.0),
              host="n0", manifest=JobManifest(job_id="j"))
    # paper: samples align to wall-clock multiples of the interval
    assert d.next_sample_time(1000.0) == 1200.0
    assert d.next_sample_time(1200.0) == 1800.0
    assert d.next_sample_time(1799.9) == 1800.0


def test_manifest_roundtrip(tmp_path):
    man = JobManifest(job_id="cobra.42", user="alice", app="gemma2-27b",
                      num_hosts=64, num_chips=256, extra={"large_memory": "1"})
    man.save(tmp_path / "m.json")
    got = JobManifest.load(tmp_path / "m.json")
    assert got == man
    assert JobManifest.load(tmp_path / "missing.json") is None
