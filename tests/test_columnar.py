"""Columnar store mechanics: sealing, zone maps, dictionary encoding,
scan pruning, dedup horizon eviction, row-compat materialization."""

import numpy as np

from repro.core.aggregator import MetricStore
from repro.core.columnar import ColumnarMetricStore
from repro.core.schema import MetricRecord, encode_line


def rec(ts, host="n0", job="j1", kind="perf", **fields):
    return MetricRecord(ts, host, job, kind, fields)


def test_buffer_seals_at_threshold():
    store = MetricStore(seal_threshold=10)
    for i in range(25):
        store.insert(rec(1000.0 + i, v=float(i)))
    assert len(store) == 25
    segs = store.segments()
    assert len(segs) == 3  # 2 sealed + transient buffer of 5
    assert segs[0].n == 10 and segs[1].n == 10 and segs[2].n == 5
    store.seal()
    assert len(store.segments()) == 3
    assert all(s.n in (10, 5) for s in store.segments())
    assert len(store) == 25


def test_segments_are_time_ordered():
    store = MetricStore(seal_threshold=100)
    for ts in (5.0, 1.0, 3.0, 2.0, 4.0):
        store.insert(rec(ts, v=ts))
    store.seal()
    seg = store.segments()[0]
    ts = seg.attrs["ts"].vals
    assert list(ts) == sorted(ts)
    assert seg.ts_min == 1.0 and seg.ts_max == 5.0


def test_zone_maps():
    store = MetricStore(seal_threshold=4)
    for i in range(8):
        store.insert(rec(1000.0 + i, v=float(i * 10)))
    segs = store.segments()
    assert segs[0].zone("v") == (0.0, 30.0)
    assert segs[1].zone("v") == (40.0, 70.0)
    # unknown columns get the conservative "never prune" zone
    assert segs[0].zone("not_there") == (-np.inf, np.inf)


def test_dictionary_encoding_and_vocab_union():
    store = MetricStore(seal_threshold=3)
    for i in range(7):
        store.insert(rec(1000.0 + i, host=f"h{i % 2}",
                         job=f"job{i % 3}", kind="perf",
                         app="gemma" if i % 2 else "qwen"))
    assert store.jobs() == ["job0", "job1", "job2"]
    assert store.kinds() == ["perf"]
    assert store.hosts() == ["h0", "h1"]
    seg = store.segments()[0]
    col = seg.cols["app"]
    assert col.kind == "str" and set(col.index) <= {"gemma", "qwen"}


def test_scan_filters_and_pruning():
    store = MetricStore(seal_threshold=5)
    for i in range(20):
        store.insert(rec(1000.0 + i, host=f"h{i % 2}",
                         job="a" if i < 10 else "b",
                         kind="perf" if i % 2 == 0 else "device",
                         v=float(i)))
    sc = store.scan(job="a", kind="perf", fields=("v",))
    vals, present = sc.field("v")
    assert sc.n == 5 and present.all()
    assert sorted(vals.tolist()) == [0.0, 2.0, 4.0, 6.0, 8.0]
    sc = store.scan(since=1010.0, until=1015.0)
    assert sc.n == 5
    assert store.scan(job="zzz").n == 0
    # str-typed field scans come back non-numeric
    store2 = MetricStore()
    store2.insert(rec(1.0, app="gemma"))
    vals, present = store2.scan(fields=("app",)).field("app")
    assert not present.any()


def test_records_and_select_compat():
    store = MetricStore(seal_threshold=4)
    for i in range(10):
        store.insert(rec(1000.0 + i, host=f"h{i % 3}", v=float(i), step=i))
    recs = store.records
    assert len(recs) == 10
    assert all(isinstance(r, MetricRecord) for r in recs)
    assert recs[0].fields["step"] == 0  # ints stay ints
    assert isinstance(recs[0].fields["step"], int)
    assert isinstance(recs[0].fields["v"], float)
    sel = list(store.select(kind="perf", since=1003.0, until=1007.0))
    assert [r.ts for r in sel] == [1003.0, 1004.0, 1005.0, 1006.0]
    # records cache invalidates on insert
    store.insert(rec(2000.0, v=99.0))
    assert len(store.records) == 11


def test_field_named_like_reserved_attr():
    # detector events carry a "host" *field*; the record attr must
    # survive while the query view shows the field (as_dict semantics)
    store = MetricStore()
    store.insert(MetricRecord(1.0, "aggregator", "j1", "event",
                              {"host": "n7", "detector": "hang"}))
    r = store.records[0]
    assert r.host == "aggregator" and r.fields["host"] == "n7"
    from repro.core.splunklite import query
    rows = query(store, "search kind=event")
    assert rows[0]["host"] == "n7"  # field overrides, like as_dict()


def test_dedup_within_horizon():
    store = MetricStore(seal_threshold=4, dedup_horizon_s=1000.0)
    r = rec(1000.0, v=1.0)
    assert store.insert(r)
    assert not store.insert(rec(1000.0, v=1.0))
    assert store.duplicates_dropped == 1


def test_dedup_eviction_past_horizon():
    store = MetricStore(seal_threshold=2, dedup_horizon_s=100.0)
    for i in range(6):
        store.insert(rec(1000.0 + i, v=float(i)))
    assert store.dedup_evicted_keys == 0
    # jump far past the horizon; sealing triggers eviction
    store.insert(rec(5000.0, v=100.0))
    store.insert(rec(5001.0, v=101.0))
    assert store.dedup_evicted_keys >= 6
    # old keys were evicted -> stale duplicates are accepted again
    assert store.insert(rec(1000.0, v=0.0))


def test_dedup_unlimited_when_horizon_none():
    store = MetricStore(seal_threshold=2, dedup_horizon_s=None)
    for i in range(10):
        store.insert(rec(1000.0 + i, v=float(i)))
    store.insert(rec(999999.0, v=1.0))
    store.seal()
    assert store.dedup_evicted_keys == 0
    assert not store.insert(rec(1000.0, v=0.0))
    assert store.duplicates_dropped == 1


def test_mixed_type_column_falls_back_to_object():
    store = MetricStore()
    store.insert(rec(1.0, x=1.5))
    store.insert(rec(2.0, x="str"))
    store.seal()
    col = store.segments()[0].cols["x"]
    assert col.kind == "obj"
    vals = [r.fields["x"] for r in store.records]
    assert vals == [1.5, "str"]


def test_store_roundtrips_wire_lines():
    store = MetricStore(seal_threshold=3)
    recs = [rec(1000.0 + i, v=float(i), app="a b c") for i in range(7)]
    store.ingest_lines(encode_line(r) for r in recs)
    assert len(store) == 7
    got = store.records
    assert [r.fields["app"] for r in got] == ["a b c"] * 7
