"""Telemetry: distributed tracing, the unified metrics registry, and
fleet self-ingestion (ISSUE 10 / docs/observability.md).

Acceptance contract: one remote fleet query produces a **single
stitched trace** spanning the coordinator and at least two worker
processes, with parent/child span IDs verified across the wire; spans
survive retries (one ``rpc.*`` span covers every attempt), hedged
scatters mark loser attempts cancelled, circuit-open fast-fails and
degraded fallbacks are visible as span statuses; and a splunklite
query over the self-ingested ``_telemetry`` store returns the fleet's
own scatter/cache/breaker metrics — including under fault injection.
"""

import json

import pytest

from conftest import random_records
from test_incremental import rows_identical

from repro.core import dashboards, detectors, telemetry as tm
from repro.core.aggregator import Aggregator, MetricStore
from repro.core.faults import FaultPlan
from repro.core.remote import RemoteShardedAggregator
from repro.core.schema import MetricRecord, encode_line
from repro.core.service import QueryService
from repro.core.shards import ShardedAggregator
from repro.core.splunklite import query
from repro.core.telemetry import (NULL_SPAN, Registry, SelfMonitor,
                                  Telemetry, Tracer, format_trace,
                                  sanitize_metric_key)

SEAL = 53
IDLE_S = 300.0  # workers self-exit if a wedged run leaks them
RECORDS = random_records(seed=11, n=420)

FLEET_Q = ("search kind=perf gflops>10 | stats avg(gflops) p90(gflops) "
           "count by job | sort -avg_gflops | head 10")


def make_traced_fleet(directory, n=2, records=RECORDS, **kw):
    agg = RemoteShardedAggregator(num_shards=n, directory=directory,
                                  seal_threshold=SEAL,
                                  worker_idle_timeout_s=IDLE_S,
                                  spawn_timeout_s=60.0,
                                  telemetry=Telemetry(tracing=True), **kw)
    for rec in records:
        agg.insert(rec)
    return agg


def spans_by_name(spans, name):
    return [s for s in spans if s["name"] == name]


# ===========================================================================
# Tracer unit behavior
# ===========================================================================

def test_span_parent_child_linkage_and_ring():
    tr = Tracer(node="t")
    root = tr.start_span("query")
    child = root.child("scatter", attrs={"shards": 2})
    grand = child.child("merge")
    grand.finish()
    child.finish()
    root.finish()
    tid, spans = tr.last_trace()
    assert tid == root.trace_id
    assert {s["name"] for s in spans} == {"query", "scatter", "merge"}
    by_name = {s["name"]: s for s in spans}
    assert by_name["query"]["parent_id"] is None
    assert by_name["scatter"]["parent_id"] == by_name["query"]["span_id"]
    assert by_name["merge"]["parent_id"] == by_name["scatter"]["span_id"]
    assert by_name["scatter"]["attrs"]["shards"] == 2
    assert all(s["trace_id"] == tid for s in spans)
    assert tr.stats()["traces_finished"] == 1


def test_disabled_tracer_is_inert():
    tr = Tracer(enabled=False)
    s = tr.start_span("query")
    assert s is NULL_SPAN and not s.recording
    assert s.child("x") is s and s.ctx() == {}
    with s:
        pass
    assert tr.last_trace() == (None, [])
    assert tr.stats()["spans_started"] == 0


def test_exception_inside_span_marks_error():
    tr = Tracer()
    with pytest.raises(ValueError):
        with tr.start_span("query"):
            raise ValueError("boom")
    _tid, spans = tr.last_trace()
    assert spans[0]["status"] == "error"


def test_attrs_set_after_finish_are_dropped():
    """The ring copies span dicts at finish time: late attrs must not
    appear (callers set attrs *inside* the ``with`` block)."""
    tr = Tracer()
    root = tr.start_span("query")
    root.set(early=1)
    root.finish()
    root.set(late=2)
    _tid, spans = tr.last_trace()
    assert spans[0]["attrs"] == {"early": 1}


def test_ring_evicts_oldest_trace():
    tr = Tracer(ring_max=2)
    tids = []
    for i in range(3):
        s = tr.start_span(f"q{i}")
        s.finish()
        tids.append(s.trace_id)
    assert tr.finished_traces() == tids[1:]
    assert tr.trace(tids[0]) == []


def test_slow_query_log_keeps_exemplar():
    tr = Tracer(slow_threshold_s=0.0)
    root = tr.start_span("query", attrs={"q": "stats count"})
    root.child("scatter").finish()
    root.finish()
    slow = tr.slow_queries()
    assert len(slow) == 1
    entry = slow[0]
    assert entry["trace_id"] == root.trace_id
    assert entry["name"] == "query"
    assert {s["name"] for s in entry["exemplar"]} == {"query", "scatter"}


def test_activate_installs_thread_local_current():
    tr = Tracer()
    assert tr.current() is NULL_SPAN
    root = tr.start_span("outer")
    with tr.activate(root):
        assert tr.current() is root
        inner = tr.start_span("inner", parent=tr.current())
        assert inner.trace_id == root.trace_id
        inner.finish()
    assert tr.current() is NULL_SPAN
    root.finish()


def test_format_trace_tree_marks_statuses():
    tr = Tracer(node="n0")
    root = tr.start_span("query")
    root.child("ok.child").finish()
    root.child("bad.child").finish("error")
    root.child("lost.child").finish("cancelled")
    root.finish()
    _tid, spans = tr.last_trace()
    txt = format_trace(spans)
    assert "n0/query" in txt
    assert "!" in txt and "x" in txt           # error + cancelled marks
    lines = txt.splitlines()
    assert len(lines) == 4
    # children render indented under the root
    assert all("  n0/" in ln for ln in lines[1:])


# ===========================================================================
# Registry
# ===========================================================================

def test_registry_instruments_and_flat_snapshot():
    reg = Registry()
    reg.counter("remote.queries").inc()
    reg.counter("remote.queries").inc(2)
    reg.gauge("pool.size", shard=3).set(7)
    h = reg.histogram("latency_s")
    h.observe(0.5)
    h.observe(1.5)
    flat = reg.flat_snapshot()
    assert flat["remote.queries"] == 3.0
    assert flat["pool.size.shard_3"] == 7.0
    assert flat["latency_s.count"] == 2.0
    assert flat["latency_s.sum"] == 2.0
    assert flat["latency_s.max"] == 1.5


def test_registry_kind_conflict_raises():
    reg = Registry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_collector_failure_is_isolated():
    reg = Registry()
    reg.register_collector("good", lambda: {"good.v": 1.0})

    def sick():
        raise RuntimeError("scrape me not")

    reg.register_collector("sick", sick)
    flat = reg.flat_snapshot()
    assert flat["good.v"] == 1.0
    assert flat["sick.collector_errors"] == 1.0
    reg.unregister_collector("sick")
    assert "sick.collector_errors" not in reg.flat_snapshot()


def test_sanitize_metric_key_preserves_dots():
    assert sanitize_metric_key("a.b.c") == "a.b.c"
    assert sanitize_metric_key("a b/c") == "a_b_c"


# ===========================================================================
# SelfMonitor + aggregator wiring
# ===========================================================================

def test_self_monitor_emits_snapshot_and_slow_events():
    tel = Telemetry(tracing=True, slow_threshold_s=0.0)
    tel.registry.counter("remote.queries").inc(5)
    tel.span("query").finish()          # lands in the slow log
    sink = MetricStore()
    mon = SelfMonitor(tel, sink, interval_s=0.0)
    assert mon.pump() == 2              # one fleet row + one slow event
    fleet = query(sink, "search kind=fleet")
    assert len(fleet) == 1
    assert fleet[0]["remote.queries"] == 5.0
    assert fleet[0]["tracer.traces_finished"] == 1.0
    events = query(sink, "search kind=event")
    assert len(events) == 1 and events[0]["event"] == "slow_query"
    # the slow entry is consumed: a second pump emits only the snapshot
    assert mon.pump() == 1


def test_aggregator_self_monitor_pumps_into_telemetry_store(tmp_path):
    inbox = tmp_path / "inbox"
    inbox.mkdir()
    agg = Aggregator(inbox, self_monitor=0.0)
    try:
        with open(inbox / "s.log", "w") as f:
            for i in range(10):
                f.write(encode_line(MetricRecord(
                    ts=100.0 + i, host="h0", job="j1", kind="perf",
                    fields={"gflops": 1.0})) + "\n")
        assert agg.pump() == 10
        rows = query(agg.telemetry_store, "search kind=fleet")
        assert rows, "self-monitor never pumped"
        # the plain store's storage collector is attached automatically
        assert rows[-1]["storage.buffer_rows"] == 10.0
    finally:
        agg.close()


# ===========================================================================
# Local sharded tracing
# ===========================================================================

def test_local_scatter_query_trace_shape(tmp_path):
    tel = Telemetry(tracing=True)
    agg = ShardedAggregator(num_shards=2, directory=tmp_path / "s",
                            seal_threshold=SEAL, telemetry=tel)
    try:
        for rec in RECORDS:
            agg.insert(rec)
        rows, stats = agg.query_with_stats(FLEET_Q)
        assert rows
        tid, spans = tel.tracer.last_trace()
        root = spans_by_name(spans, "query")[0]
        assert root["attrs"]["q"] == FLEET_Q
        assert root["attrs"]["shards"] == 2
        kids = {s["name"] for s in spans
                if s["parent_id"] == root["span_id"]}
        assert {"plan.compile", "scatter", "merge", "finalize"} <= kids
    finally:
        agg.close()


# ===========================================================================
# Remote fleet: the stitched-trace acceptance criterion
# ===========================================================================

def test_remote_trace_stitches_coordinator_and_two_workers(tmp_path):
    agg = make_traced_fleet(tmp_path / "fleet", n=2)
    try:
        rows = query(agg, FLEET_Q)
        assert rows
        tid, spans = agg.telemetry.tracer.last_trace()
        assert tid is not None
        assert all(s["trace_id"] == tid for s in spans)
        worker_spans = [s for s in spans
                        if s["node"].startswith("worker:")]
        worker_nodes = {s["node"] for s in worker_spans}
        assert len(worker_nodes) >= 2, (
            f"expected spans from >=2 worker processes, got {worker_nodes}")
        # every worker span's parent is a coordinator-side span
        coord_ids = {s["span_id"] for s in spans
                     if not s["node"].startswith("worker:")}
        for w in worker_spans:
            assert w["parent_id"] in coord_ids, w
        root = spans_by_name(spans, "query")[0]
        assert root["parent_id"] is None
        shard_spans = spans_by_name(spans, "shard.scatter")
        assert {s["attrs"]["shard"] for s in shard_spans} == {0, 1}
        # the tree renders without orphans: one line per span
        assert len(format_trace(spans).splitlines()) == len(spans)
    finally:
        agg.close()


def test_trace_negotiation_skips_incapable_workers(tmp_path):
    """A worker that did not advertise trace support at hello gets no
    trace context and ships no spans — the coordinator trace is still
    complete on its side (old-worker interop)."""
    agg = make_traced_fleet(tmp_path / "fleet", n=1)
    try:
        for sh in agg.shards:
            assert sh.trace_capable     # negotiated at hello
            sh.trace_capable = False    # pretend it's an old worker
        rows = query(agg, FLEET_Q)
        assert rows
        _tid, spans = agg.telemetry.tracer.last_trace()
        assert not [s for s in spans if s["node"].startswith("worker:")]
        assert spans_by_name(spans, "shard.scatter")
    finally:
        agg.close()


def test_retried_rpc_stays_one_span_with_attempt_count(tmp_path):
    plan = FaultPlan(0)
    agg = make_traced_fleet(tmp_path / "fleet", n=1, records=RECORDS[:60],
                            fault_plan=plan)
    try:
        tracer = agg.telemetry.tracer
        root = tracer.start_span("test.root")
        with tracer.activate(root):
            plan.force("recv", "drop")  # lose exactly one reply
            agg.shards[0].rpc("explain", fingerprint="")
        root.finish()
        assert agg.robustness_stats()["retries"] >= 1
        spans = tracer.trace(root.trace_id)
        rpc = spans_by_name(spans, "rpc.explain")
        assert len(rpc) == 1, "retries must not fork extra rpc spans"
        assert rpc[0]["attrs"]["attempts"] >= 2
        assert rpc[0]["status"] == "ok"
        assert rpc[0]["parent_id"] == root.span_id
    finally:
        agg.close()


def test_circuit_open_and_degraded_fallback_spans(tmp_path):
    agg = make_traced_fleet(tmp_path / "fleet", n=2,
                            breaker_threshold=1, breaker_reset_s=60.0)
    try:
        want = query(agg, FLEET_Q)
        agg.kill_worker(1)
        # first query: the dead worker trips the breaker, shard 1 is
        # served degraded (read-only local fallback)
        rows_identical(query(agg, FLEET_Q), want, FLEET_Q)
        # second query: the open breaker fast-fails the scatter
        rows_identical(query(agg, FLEET_Q), want, FLEET_Q)
        assert agg.last_query_stats["degraded_shards"] == 1
        _tid, spans = agg.telemetry.tracer.last_trace()
        failed = [s for s in spans_by_name(spans, "shard.scatter")
                  if s["status"] == "error"]
        assert failed and failed[0]["attrs"]["shard"] == 1
        assert failed[0]["attrs"]["circuit_open"] is True
        degraded = spans_by_name(spans, "shard.degraded")
        assert degraded and degraded[0]["attrs"]["shard"] == 1
        assert degraded[0]["status"] == "ok"
    finally:
        agg.close()


def test_hedged_scatter_cancels_loser_attempt_spans(tmp_path):
    agg = make_traced_fleet(tmp_path / "fleet", n=2, replicas=2,
                            hedge_delay_s=0.02)
    try:
        agg.sync_replicas()
        sh = agg.shards[0]
        slow = sh._read_order()[0]      # whoever is preferred right now
        slow.rpc("set_delay", s=0.5)
        rows = query(agg, FLEET_Q)
        assert rows
        assert agg.last_query_stats["hedged_shards"] >= 1
        _tid, spans = agg.telemetry.tracer.last_trace()
        hedges = spans_by_name(spans, "hedge.attempt")
        assert hedges, "hedge fired but produced no attempt span"
        cancelled = [s for s in spans
                     if s["name"] in ("hedge.attempt", "attempt")
                     and s["status"] == "cancelled"]
        assert cancelled, "the losing attempt must be marked cancelled"
        # the winner's worker span was adopted into the same trace
        assert [s for s in spans if s["node"].startswith("worker:")]
    finally:
        agg.close()


# ===========================================================================
# Self-ingestion: splunklite over the fleet's own vitals
# ===========================================================================

def test_fleet_vitals_queryable_including_under_faults(tmp_path):
    plan = FaultPlan(0)
    agg = make_traced_fleet(tmp_path / "fleet", n=2, records=RECORDS[:120],
                            fault_plan=plan)
    try:
        plan.force("recv", "drop")      # one retry on the insert path
        assert agg.insert(MetricRecord(99999.0, "n0", "vitals.1", "perf",
                                       {"gflops": 11.0}))
        rows = query(agg, FLEET_Q)
        assert rows
        sink = MetricStore()
        mon = SelfMonitor(agg.telemetry, sink, interval_s=0.0)
        assert mon.pump() >= 1
        fleet = query(sink, "search kind=fleet")
        assert len(fleet) == 1
        row = fleet[0]
        # scatter, cache, breaker, and robustness metrics all present
        assert row["remote.queries"] >= 1.0
        assert row["remote.retries"] >= 1.0
        assert row["shards.scatter_queries"] >= 1.0
        assert row["breaker.breakers"] == 2.0
        assert row["breaker.open"] == 0.0
        assert "cache.partial.hits" in row
        assert row["tracer.traces_finished"] >= 1.0
        # field names survive the splunklite grammar: filter on one
        hot = query(sink, "search kind=fleet remote.queries>0")
        assert len(hot) == 1
    finally:
        agg.close()


# ===========================================================================
# Dashboards + detectors over the _telemetry store
# ===========================================================================

def _snapshot_record(ts, fields):
    return MetricRecord(ts=ts, host="fleet-coordinator", job="_fleet",
                        kind="fleet", fields=fields)


def test_view_fleet_health_uses_latest_snapshot():
    sink = MetricStore()
    sink.insert(_snapshot_record(1.0, {"remote.queries": 1.0,
                                       "breaker.open": 0.0}))
    sink.insert(_snapshot_record(2.0, {"remote.queries": 5.0,
                                       "breaker.open": 1.0}))
    rows = dashboards.view_fleet_health(sink)
    got = {r["metric"]: r["value"] for r in rows}
    assert got == {"remote.queries": 5.0, "breaker.open": 1.0}
    table = dashboards.markdown_table(rows)
    assert "remote.queries" in table


def test_streaming_fleet_health_rerenders_only_on_change():
    sink = MetricStore()
    sink.insert(_snapshot_record(1.0, {"remote.queries": 1.0}))
    view = dashboards.streaming_fleet_health(sink)
    assert view.rendered() and view.renders == 1
    view.rendered()
    assert view.renders == 1            # unchanged vitals: no re-render
    sink.insert(_snapshot_record(2.0, {"remote.queries": 2.0}))
    assert "| remote.queries | 2 |" in view.rendered()
    assert view.renders == 2


def test_view_slow_queries_orders_worst_first():
    sink = MetricStore()
    for i, dur in enumerate((0.1, 0.9, 0.5)):
        sink.insert(MetricRecord(
            ts=float(i), host="c", job="_fleet", kind="event",
            fields={"event": "slow_query", "trace_id": f"t{i}",
                    "name": "query", "duration_s": dur}))
    rows = dashboards.view_slow_queries(sink, limit=2)
    assert [r["duration_s"] for r in rows] == [0.9, 0.5]
    assert rows[0]["trace_id"] == "t1"


def test_breaker_open_detector_fires_on_latest_snapshot():
    sink = MetricStore()
    sink.insert(_snapshot_record(1.0, {"breaker.open": 2.0,
                                       "breaker.opens": 3.0}))
    sink.insert(_snapshot_record(2.0, {"breaker.open": 0.0,
                                       "breaker.opens": 3.0}))
    # breaker closed again by the newest snapshot: no event
    assert detectors.BreakerOpenDetector().scan(sink) == []
    sink.insert(_snapshot_record(3.0, {"breaker.open": 1.0,
                                       "breaker.opens": 4.0}))
    evs = detectors.BreakerOpenDetector().scan(sink)
    assert len(evs) == 1
    assert evs[0].severity == "critical"
    assert evs[0].fields == {"open": 1, "opens": 4}
    # events write back as queryable records
    detectors.DetectorBank.write_back(sink, evs)
    assert query(sink, "search kind=event")


def test_quarantine_growth_detector_needs_actual_growth():
    sink = MetricStore()
    sink.insert(_snapshot_record(1.0, {"storage.quarantined_segments": 2.0}))
    sink.insert(_snapshot_record(2.0, {"storage.quarantined_segments": 2.0}))
    assert detectors.QuarantineGrowthDetector().scan(sink) == []
    sink.insert(_snapshot_record(3.0, {"storage.quarantined_segments": 4.0}))
    evs = detectors.QuarantineGrowthDetector().scan(sink)
    assert len(evs) == 1
    assert evs[0].severity == "warning"
    assert evs[0].fields["growth"] == 2


def test_telemetry_detectors_stay_out_of_default_bank():
    assert set(detectors.TELEMETRY_DETECTORS).isdisjoint(
        detectors.DEFAULT_DETECTORS)
    bank = detectors.DetectorBank()
    assert not any(isinstance(d, detectors.BreakerOpenDetector)
                   for d in bank.detectors)


# ===========================================================================
# QueryService: one consistent stats snapshot
# ===========================================================================

def test_query_service_stats_is_an_independent_snapshot(tmp_path):
    agg = ShardedAggregator(num_shards=2, directory=tmp_path / "s",
                            seal_threshold=SEAL,
                            telemetry=Telemetry(tracing=True))
    svc = QueryService(agg)
    try:
        for rec in RECORDS[:120]:
            agg.insert(rec)
        svc.submit(FLEET_Q).result()
        a = svc.stats()
        assert a["executed"] >= 1
        a["executed"] = 10 ** 9                 # mutate the copy
        assert svc.stats()["executed"] < 10 ** 9
        # the service registers on the shared registry: its numbers show
        # up in the same flat snapshot as the shard/storage collectors
        flat = agg.telemetry.registry.flat_snapshot()
        assert flat["service.executed"] >= 1.0
        assert "shards.scatter_queries" in flat
    finally:
        svc.close()
        agg.close()


# ===========================================================================
# Ops CLI
# ===========================================================================

def test_cli_demo_prints_trace_and_self_ingestion(capsys):
    assert tm.main(["demo", "--shards", "1"]) == 0
    out = capsys.readouterr().out
    assert "coordinator/query" in out
    assert '"kind": "fleet"' in out


def test_cli_trace_renders_span_dump(tmp_path, capsys):
    tr = Tracer(node="n9")
    root = tr.start_span("query")
    root.child("scatter").finish()
    root.finish()
    _tid, spans = tr.last_trace()
    path = tmp_path / "trace.json"
    path.write_text(json.dumps({"spans": spans}))
    assert tm.main(["trace", str(path)]) == 0
    out = capsys.readouterr().out
    assert "n9/query" in out and "n9/scatter" in out
