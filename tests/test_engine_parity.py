"""Engine parity: every splunklite query must return identical results
through the legacy row executor and the columnar executor.

Randomized stores (mixed field presence, NaN values, string fields,
multiple sealed segments plus an unsealed buffer) are queried through
both paths; rows are compared order-sensitively with numeric tolerance.
"""

import pytest

# shared generators/comparators live in conftest so the engine-parity,
# persistence, and shard-fan-out suites drive one workload definition
from conftest import (assert_rows_equal, both_engines,  # noqa: F401
                      random_store)
from repro.core.aggregator import MetricStore
from repro.core.schema import MetricRecord
from repro.core.splunklite import query


SEARCH_QUERIES = [
    "search kind=perf",
    "search kind=perf job=alpha.1",
    "search gflops>500",
    "search gflops<=250 kind=perf",
    "search step>=10 step<30",
    "search app=gem*",
    "search app!=gemma",
    "search job=*a*",
    "search gemma",
    "search missingfield=x",
    "search missingfield!=x",
    "search kind=perf | where gflops>100 | where step<40",
]

AGG_QUERIES = [
    "search kind=perf | stats count",
    "search kind=perf | stats count(gflops) count(app) by job",
    "search kind=perf | stats avg(gflops) sum(gflops) min(gflops) "
    "max(gflops) by host",
    "stats median(gflops) p25(gflops) p75(gflops) p90(gflops) p95(gflops) "
    "p99(gflops) by job",
    "stats stdev(gflops) range(gflops) dc(host) dc(app) dc(step) by kind",
    "search kind=perf | stats first(app) last(app) first(step) last(gflops)",
    "stats avg(gflops) as g max(step) as s by job host",
    "stats count by step",          # numeric group keys
    "stats count by app",           # group key with missing values
    "stats count by job host app",  # multi string keys (dict fast path)
    "stats avg(gflops) dc(step) by app job",  # multi keys w/ missing rows
    "stats count by app kind",      # missing + reserved-attr key mix
    "search kind=perf | timechart span=30 avg(gflops) count",
    "timechart span=100 p90(gflops) max(step) by job",
    "timechart span=45 avg(mfu) by host app",
]

PIPELINE_QUERIES = [
    "search kind=perf | sort -gflops | head 7",
    "search kind=perf | sort gflops | head 12",
    "sort -app gflops | head 25",   # mixed string/num keys + desc
    "sort mfu | head 30",           # many rows missing the key
    "search kind=perf | dedup host",
    "dedup job app",
    "dedup step",
    "search kind=perf | fields host gflops step | head 9",
    "head 5",
    "search kind=perf | eval tflops=gflops/1000 | head 6",
    "eval r=gflops/(step-10) | stats avg(r) count(r)",  # div-by-zero -> nan
    "eval z=log(gflops-500) | stats count avg(z)",      # log(<=0) -> nan
    "eval s=sqrt(gflops-500) | stats avg(s)",
    "eval m=min(gflops,step) | sort -m | head 8",
    "eval hot=gflops>750 | stats sum(hot) by job",
    "eval hot=gflops>750 | stats count by hot",    # bool str group keys
    "eval b=floor(gflops/100) | stats count by b",  # int str group keys
    "eval b=floor(gflops/100)+1 | stats count by b",  # nested int func
    "eval k=5 | stats count by k",                  # constant int eval
    "search kind=perf | stats sum(nosuchfield) by job",  # sum([]) is 0
    "eval b=(gflops+1)%7 | stats avg(b)",
    "eval c=gflops if step>25 else mfu | stats avg(c)",
    "search kind=perf | eval x=missing*2 | stats count(x) avg(x)",
    "search kind=perf | stats avg(gflops) by job "
    "| eval t=avg_gflops/1000 | sort -t",
    "search kind=perf | timechart span=60 avg(gflops) by job "
    "| sort -avg_gflops | head 4",
]


@pytest.mark.parametrize("q", SEARCH_QUERIES)
def test_search_parity(q):
    both_engines(random_store(), q)


@pytest.mark.parametrize("q", AGG_QUERIES)
def test_agg_parity(q):
    both_engines(random_store(), q)


@pytest.mark.parametrize("q", PIPELINE_QUERIES)
def test_pipeline_parity(q):
    both_engines(random_store(), q)


@pytest.mark.parametrize("seed", range(5))
def test_randomized_store_parity(seed):
    store = random_store(seed=seed, n=150 + seed * 70,
                         seal_threshold=41 + seed * 13)
    for q in ("search kind=perf gflops>10 | stats avg(gflops) "
              "p90(gflops) count by job | sort -avg_gflops | head 10",
              "stats dc(host) median(gflops) by kind job",
              "search app=q* | timechart span=90 count by host",
              "sort -gflops step | head 20",
              "dedup host app | fields host app gflops"):
        both_engines(store, q)


def test_parity_with_fieldless_first_fallback():
    # field-less first/dc aggregate whole row dicts -> columnar engine
    # falls back mid-pipeline; results must still match
    both_engines(random_store(), "search kind=perf | stats count first")


def test_parity_eval_on_mixed_type_column():
    # a field holding both strings and numbers lands in an obj column;
    # eval must fall back to the row engine, not silently produce NaN
    store = MetricStore()
    store.insert(MetricRecord(1.0, "h", "j", "perf", {"status": "ok"}))
    store.insert(MetricRecord(2.0, "h", "j", "perf", {"status": 5}))
    store.insert(MetricRecord(3.0, "h", "j", "perf", {"gflops": 2.0}))
    rows = both_engines(store, "eval x=status+1 | fields ts x")
    assert any(r.get("x") == 6.0 for r in rows)


def test_parity_empty_store():
    store = MetricStore()
    for q in ("search kind=perf", "stats count", "stats avg(x) by job",
              "timechart span=10 count", "sort -x | head 3", "dedup a"):
        both_engines(store, q)


def test_parity_small_buffer_only_store():
    store = MetricStore(seal_threshold=10_000)  # nothing sealed
    for i in range(25):
        store.insert(MetricRecord(1000.0 + i, f"h{i % 2}", "j", "perf",
                                  {"v": float(i)}))
    both_engines(store, "stats avg(v) p50(v) by host")
    both_engines(store, "search v>5 | sort -v | head 4")


def test_engine_kwarg_validation():
    from repro.core.splunklite import QueryError
    with pytest.raises(QueryError):
        query([{"a": 1}], "stats count", engine="columnar")
