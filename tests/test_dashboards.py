"""Dashboard rendering + specialized views (paper §4.4)."""

import numpy as np

from repro.core.aggregator import MetricStore
from repro.core.daemon import JobManifest
from repro.core.dashboards import (JobPoint, job_metric_series,
                                   job_statistical_view, markdown_table,
                                   render_roofline_svg,
                                   render_timeseries_svg, roofline_points,
                                   view_idle_accelerators,
                                   view_low_participation,
                                   view_memory_underuse,
                                   view_top_apps_by_device_hours)
from repro.core.schema import MetricRecord


def build_store():
    store = MetricStore()
    manifests = {}
    for j, (app, g, frac) in enumerate([
            ("gemma2-27b", 900.0, 0.7), ("qwen3-8b", 300.0, 0.6),
            ("idle-app", 50.0, 0.01)]):
        job = f"j{j}"
        manifests[job] = JobManifest(job_id=job, app=app, num_hosts=2,
                                     num_chips=8,
                                     extra={"large_memory": "1"})
        for h in range(2):
            for s in range(10):
                store.insert(MetricRecord(
                    1000.0 + s * 60, f"n{j}{h}", job, "perf",
                    {"gflops": g + s, "gflops_per_chip": (g + s) / 8,
                     "ai": 10.0 + j, "mfu": 0.4, "step_time_s": 1.0}))
                store.insert(MetricRecord(
                    1000.0 + s * 60, f"n{j}{h}", job, "device",
                    {"hbm_frac_used": frac, "local_devices": 4}))
        store.insert(MetricRecord(1000.0, f"n{j}0", job, "meta",
                                  {"app": app}))
    return store, manifests


def test_roofline_points_and_svg():
    store, manifests = build_store()
    pts = roofline_points(store, manifests)
    assert len(pts) == 3
    svg = render_roofline_svg(pts)
    assert svg.startswith("<svg") and svg.count("<circle") >= 3
    assert "GFLOP/s per chip" in svg
    # empty store still renders axes
    assert render_roofline_svg([]).startswith("<svg")


def test_timeseries_svg():
    series = {"n0": [(0.0, 1.0), (60.0, 2.0)], "n1": [(0.0, 1.5)]}
    svg = render_timeseries_svg(series, "t", "gflops")
    assert "<polyline" in svg
    assert render_timeseries_svg({}, "t", "y").count("no data") == 1


def test_job_series_and_statistical_view():
    store, _ = build_store()
    series = job_metric_series(store, "j0", "gflops")
    assert set(series) == {"n00", "n01"} and len(series["n00"]) == 10
    stat = job_statistical_view(store, "j0", "gflops", span_s=60)
    assert set(stat) == {"min", "median", "max"}
    for b_min, b_med, b_max in zip(stat["min"], stat["median"],
                                   stat["max"]):
        assert b_min[1] <= b_med[1] <= b_max[1]


def test_specialized_views():
    store, manifests = build_store()
    top = view_top_apps_by_device_hours(store, manifests)
    assert top and top[0]["device_hours"] >= top[-1]["device_hours"]
    idle = view_idle_accelerators(store)
    assert [r["job"] for r in idle] == ["j2"]
    mem = view_memory_underuse(store, manifests)
    assert [r["job"] for r in mem] == ["j2"]
    # every host reports work -> no low-participation rows
    assert view_low_participation(store, manifests) == []


def test_markdown_table():
    md = markdown_table([{"a": 1, "b": 2.5}, {"a": 3, "b": 4.0}])
    assert md.count("|") > 6 and "2.5" in md
    assert markdown_table([]) == "*(empty)*\n"
