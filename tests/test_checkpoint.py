"""Checkpoint manager: roundtrip, atomic commit, corruption tolerance,
retention — the restart path the elastic supervisor relies on."""

import shutil

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def tree():
    return {"params": {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
                       "b": {"c": np.ones(5, dtype=np.int32)}},
            "opt": {"count": np.int32(7),
                    "mu": {"a": np.zeros((3, 4), np.float32)}}}


def assert_tree_equal(a, b):
    import jax
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(10, tree(), extra_meta={"arch": "qwen3-8b"})
    step, got, meta = cm.restore(10)
    assert step == 10 and meta["arch"] == "qwen3-8b"
    assert_tree_equal(got, tree())


def test_restore_latest_and_retention(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    for s in (10, 20, 30):
        cm.save(s, tree())
    assert cm.list_steps() == [20, 30]
    step, _, _ = cm.restore_latest()
    assert step == 30


def test_uncommitted_checkpoint_ignored(tmp_path):
    cm = CheckpointManager(tmp_path, keep=5)
    cm.save(10, tree())
    p = cm.save(20, tree())
    (p / "COMMITTED").unlink()  # simulate crash before commit marker
    assert cm.list_steps() == [10]
    step, _, _ = cm.restore_latest()
    assert step == 10


def test_corrupt_latest_falls_back(tmp_path):
    cm = CheckpointManager(tmp_path, keep=5)
    cm.save(10, tree())
    p = cm.save(20, tree())
    (p / "manifest.json").write_text("{corrupt")
    step, _, _ = cm.restore_latest()
    assert step == 10


def test_restore_missing_raises(tmp_path):
    cm = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        cm.restore(99)


def test_namedtuple_roundtrip(tmp_path):
    from repro.optim.optimizer import OptState
    import jax.numpy as jnp
    cm = CheckpointManager(tmp_path)
    state = OptState(count=jnp.int32(3), mu={"w": jnp.ones((2, 2))},
                     nu={"w": jnp.zeros((2, 2))})
    cm.save(1, {"opt": state})
    _, got, _ = cm.restore(1, namedtuple_types={"OptState": OptState})
    assert isinstance(got["opt"], OptState)
    assert int(got["opt"].count) == 3
