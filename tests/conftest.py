import sys
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# Property tests use hypothesis when available; otherwise install the
# deterministic mini-shim so the suite still collects and runs (with a
# reduced number of pseudo-random examples per property).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, str(Path(__file__).resolve().parent))
    import _mini_hypothesis
    _mini_hypothesis.install()

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see the real (single) device; only
# launch/dryrun.py (run as its own process) forces 512 devices.

# ---------------------------------------------------------------------------
# Shared store/record generators + row comparison helpers for the three
# parity suites (engine parity, segment persistence, shard fan-out).
# Import directly: ``from conftest import random_store, assert_rows_equal``.
# ---------------------------------------------------------------------------

import math  # noqa: E402

import numpy as np  # noqa: E402


def random_records(seed=0, n=400):
    """Randomized metric records: mixed field presence, NaN values,
    string fields, numeric (int and float) fields; strictly increasing
    unique timestamps so row order is canonical across store layouts."""
    from repro.core.schema import MetricRecord
    rng = np.random.default_rng(seed)
    jobs = ["alpha.1", "beta.2", "gamma.3"]
    hosts = ["n0", "n1", "n2", "n3"]
    kinds = ["perf", "device", "meta"]
    apps = ["gemma", "qwen", "mamba"]
    records = []
    for i in range(n):
        fields = {}
        if rng.random() < 0.9:
            fields["gflops"] = float(rng.uniform(0, 1000))
        if rng.random() < 0.08:
            fields["gflops"] = float("nan")
        if rng.random() < 0.7:
            fields["step"] = int(rng.integers(0, 50))
        if rng.random() < 0.5:
            fields["app"] = apps[int(rng.integers(0, len(apps)))]
        if rng.random() < 0.3:
            fields["mfu"] = float(rng.uniform(0, 1))
        records.append(MetricRecord(
            ts=1000.0 + i * 3.0,
            host=hosts[int(rng.integers(0, len(hosts)))],
            job=jobs[int(rng.integers(0, len(jobs)))],
            kind=kinds[int(rng.integers(0, len(kinds)))],
            fields=fields))
    return records


def random_store(seed=0, n=400, seal_threshold=97, directory=None,
                 shards=None, policy="hash", records=None):
    """Store with several sealed segments + a live buffer over
    :func:`random_records`.  ``directory`` makes it durable so
    persistence tests can reload the exact same workload from disk;
    ``shards``/``policy`` build a :class:`ShardedAggregator` over the
    same record stream instead (policy may be a callable for skewed
    shard-size tests)."""
    if records is None:
        records = random_records(seed=seed, n=n)
    if shards is None:
        from repro.core.aggregator import MetricStore
        store = MetricStore(seal_threshold=seal_threshold,
                            directory=directory)
    else:
        from repro.core.shards import ShardedAggregator
        store = ShardedAggregator(num_shards=shards, policy=policy,
                                  seal_threshold=seal_threshold,
                                  directory=directory)
    for rec in records:
        store.insert(rec)
    return store


def _value_eq(a, b, tol=1e-9):
    if isinstance(a, float) and isinstance(b, float):
        if math.isnan(a) and math.isnan(b):
            return True
    if isinstance(a, (int, float)) and isinstance(b, (int, float)) and \
            not isinstance(a, bool) and not isinstance(b, bool):
        fa, fb = float(a), float(b)
        if math.isnan(fa) or math.isnan(fb):
            return math.isnan(fa) == math.isnan(fb)
        return fa == fb or abs(fa - fb) <= tol * max(1.0, abs(fa), abs(fb))
    return a == b


def assert_rows_equal(got, want, q):
    """Order-sensitive row-list equality with numeric tolerance."""
    assert len(got) == len(want), \
        f"{q!r}: {len(got)} rows vs {len(want)} expected"
    for i, (g, w) in enumerate(zip(got, want)):
        assert set(g) == set(w), f"{q!r} row {i}: keys {set(g)} != {set(w)}"
        for k in w:
            assert _value_eq(g[k], w[k]), \
                f"{q!r} row {i} field {k}: {g[k]!r} != {w[k]!r}"


def both_engines(store, q):
    """Columnar vs legacy-row-executor parity check; returns the rows."""
    from repro.core.splunklite import query
    got = query(store, q)  # auto -> columnar
    want = query(store, q, engine="rows")  # legacy row oracle
    assert_rows_equal(got, want, q)
    return got
