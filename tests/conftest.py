import sys
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# Property tests use hypothesis when available; otherwise install the
# deterministic mini-shim so the suite still collects and runs (with a
# reduced number of pseudo-random examples per property).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, str(Path(__file__).resolve().parent))
    import _mini_hypothesis
    _mini_hypothesis.install()

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see the real (single) device; only
# launch/dryrun.py (run as its own process) forces 512 devices.
