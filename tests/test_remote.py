"""Remote shard execution: wire codec properties, worker lifecycle,
and the remote parity sweep.

Acceptance contract (ISSUE 5 / docs/remote.md): the shared parity
query sweep — including dashboards and detectors — returns
**byte-identical** rows on a :class:`RemoteShardedAggregator` (shards
in worker processes) vs the in-process :class:`ShardedAggregator`, for
shard counts {1, 2, 4}, including after a worker restart and in
degraded (dead-worker fallback) mode.  Byte-identical is possible
because both sides run the same partial/merge/finalize algebra in the
same deterministic order and the wire codec round-trips every float
exactly (shortest-repr JSON serialization).
"""

import json
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import assert_rows_equal, random_records, random_store
from test_engine_parity import AGG_QUERIES, PIPELINE_QUERIES, SEARCH_QUERIES
from test_incremental import rows_identical

from repro.core import remote as rm
from repro.core.columnar import ColumnarMetricStore
from repro.core.remote import (LocalWorkerProcess, RemoteShardedAggregator,
                               WorkerClient, decode_partial_map,
                               decode_rows, decode_value, encode_partial_map,
                               encode_rows, encode_value)
from repro.core.schema import MetricRecord, encode_line
from repro.core.shards import ShardedAggregator
from repro.core.sketches import P2Summary
from repro.core.splunklite import (ScatterPlan, _split_pipeline,
                                   compile_scatter_plan, query,
                                   scatter_partials, merge_partial_maps,
                                   finalize_partial_rows, run_stages)

ALL_QUERIES = SEARCH_QUERIES + AGG_QUERIES + PIPELINE_QUERIES
REMOTE_SHARD_COUNTS = [1, 2, 4]
SEAL = 53
IDLE_S = 300.0  # workers self-exit if a wedged run leaks them

RECORDS = random_records(seed=5, n=420)

FLEET_Q = ("search kind=perf gflops>10 | stats avg(gflops) p90(gflops) "
           "count by job | sort -avg_gflops | head 10")


def wire_trip(obj):
    """Encode → strict JSON → decode (what actually crosses a socket).
    ``allow_nan=False`` proves the payload never leans on Python's
    non-standard NaN/Infinity JSON extensions."""
    return json.loads(json.dumps(obj, allow_nan=False))


def make_remote(directory, n, records=RECORDS):
    agg = RemoteShardedAggregator(num_shards=n, directory=directory,
                                  seal_threshold=SEAL,
                                  worker_idle_timeout_s=IDLE_S)
    for rec in records:
        agg.insert(rec)
    return agg


# ===========================================================================
# Value codec: every partial kind round-trips (satellite)
# ===========================================================================

PARTIAL_STATE_CASES = [
    # count
    ("count", 0), ("count", 17),
    # sum/avg: (n, sum)
    ("sum", (0, 0.0)), ("avg", (3, 1.5)), ("sum", (2, -0.0)),
    # min/max/range: (n, min, max) — empty groups carry ±inf
    ("min", (0, math.inf, -math.inf)), ("max", (4, -1.25, 7.5)),
    ("range", (1, 3.0, 3.0)),
    # stdev (Welford): (n, mean, M2)
    ("stdev", (0, 0.0, 0.0)), ("stdev", (5, 2.0, 3.75)),
    # dc: exact label sets (strings, incl. the missing-label "")
    ("dc", set()), ("dc", {"a", "b", ""}), ("dc", {"42", "3.5"}),
    # quantiles: lists of P2Summary — empty, raw<=32, knotted
    ("p90", [P2Summary.from_values([], 0.9)]),
    ("p50", [P2Summary.from_values([1.0, 2.0, 3.0], 0.5)]),
    ("p99", [P2Summary.from_values(list(np.linspace(0, 1, 100)), 0.99)]),
    ("median", [P2Summary.from_values([5.0] * 40, 0.5),
                P2Summary.from_values([1.0], 0.5)]),
]


def test_codec_round_trips_every_partial_kind():
    for name, state in PARTIAL_STATE_CASES:
        back = decode_value(wire_trip(encode_value(state)))
        assert back == state, (name, state, back)
        assert type(back) is type(state), (name, state, back)


def test_codec_round_trips_nonfinite_and_scalars():
    for v in [math.inf, -math.inf, 0.0, -0.0, 1e-300, 1.5, 3, True, False,
              None, "", "häst", "a b=c"]:
        back = decode_value(wire_trip(encode_value(v)))
        assert back == v and type(back) is type(v), v
        if isinstance(v, float):
            assert math.copysign(1.0, back) == math.copysign(1.0, v)
    nan_back = decode_value(wire_trip(encode_value(math.nan)))
    assert isinstance(nan_back, float) and math.isnan(nan_back)


def test_codec_round_trips_rows_and_keys():
    rows = [{"host": "n0", "gflops": 812.25, "step": 7, "ok": True},
            {"host": "n1", "v": math.nan, "s": "x=1 y=2"},
            {}]
    back = decode_rows(wire_trip(encode_rows(rows)))
    rows_identical(back, rows, "<rows codec>")
    key = (1080.0, "alpha.1", "", "7")  # timechart bucket + labels
    assert decode_value(wire_trip(encode_value(key))) == key
    # tuple/list/set distinction survives (merge kernels rely on it)
    assert decode_value(wire_trip(encode_value((1, 2.0)))) == (1, 2.0)
    assert decode_value(wire_trip(encode_value([1, 2.0]))) == [1, 2.0]
    assert decode_value(wire_trip(encode_value({"x"}))) == {"x"}


def test_codec_rejects_unknown():
    with pytest.raises(TypeError):
        encode_value(object())
    with pytest.raises(rm.RemoteProtocolError):
        decode_value(["zz", []])
    with pytest.raises(rm.RemoteProtocolError):
        decode_value(["f", "huge"])
    with pytest.raises(ValueError):
        P2Summary.from_state(("bad",))


MERGEABLE = [q for q in ALL_QUERIES
             if compile_scatter_plan(_split_pipeline(q)) is not None]


@given(st.integers(min_value=0, max_value=200))
@settings(max_examples=15, deadline=None)
def test_codec_merge_finalize_parity_property(seed):
    """Property (satellite): encode/decode every per-shard partial map
    of every mergeable parity query, merge + finalize the *decoded*
    maps, and require byte-identical rows vs the in-process sharded
    path.  Covers count/sum/minmax/Welford/dc/P² (raw and knotted) and
    empty groups on randomized workloads."""
    from repro.core.splunklite import _Fallback
    recs = random_records(seed=seed, n=120)
    sharded = random_store(records=recs, shards=3, seal_threshold=17)
    for q in MERGEABLE[:: 4 if seed % 3 else 1]:  # rotate coverage
        plan = compile_scatter_plan(_split_pipeline(q))
        try:
            maps = [scatter_partials(s, plan) for s in sharded.shards]
        except _Fallback:
            continue  # runtime fallback (e.g. bool eval): exact-gather
            # territory, exercised by the full remote parity sweep
        wired = [decode_partial_map(wire_trip(encode_partial_map(m)))
                 for m in maps]
        for m, w in zip(maps, wired):
            assert w == m, q
        rows = run_stages(
            finalize_partial_rows(merge_partial_maps(wired, plan.aggs),
                                  plan), plan.tail)
        rows_identical(rows, sharded.query(q), q)


def test_plan_state_round_trip_preserves_fingerprint():
    for q in MERGEABLE:
        plan = compile_scatter_plan(_split_pipeline(q))
        back = ScatterPlan.from_state(wire_trip(plan.state()))
        assert back.fingerprint == plan.fingerprint, q
        assert back.cmd == plan.cmd and back.span == plan.span, q
        assert back.by == list(plan.by) and back.tail == [
            list(t) for t in plan.tail], q
    with pytest.raises(ValueError):
        ScatterPlan.from_state({"v": 999})
    with pytest.raises(ValueError):
        ScatterPlan.from_state({"v": 1, "terms": []})


# ===========================================================================
# Remote parity sweep: shard counts {1, 2, 4}
# ===========================================================================

@pytest.fixture(scope="module", params=REMOTE_SHARD_COUNTS)
def remote_pair(request, tmp_path_factory):
    n = request.param
    inproc = random_store(records=RECORDS, shards=n, seal_threshold=SEAL)
    agg = make_remote(tmp_path_factory.mktemp(f"remote{n}") / "fleet", n)
    yield inproc, agg
    agg.close()
    inproc.close()


def test_remote_parity_full_sweep(remote_pair):
    inproc, agg = remote_pair
    assert len(agg) == len(inproc) == len(RECORDS)
    for q in ALL_QUERIES:
        rows_identical(query(agg, q), query(inproc, q), q)


def test_remote_rows_engine_oracle(remote_pair):
    inproc, agg = remote_pair
    for q in (FLEET_Q, "search kind=perf | dedup host", "head 5"):
        rows_identical(query(agg, q, engine="rows"),
                       query(inproc, q, engine="rows"), q)


def test_remote_store_surface(remote_pair):
    inproc, agg = remote_pair
    assert agg.jobs() == inproc.jobs()
    assert agg.kinds() == inproc.kinds()
    assert agg.hosts() == inproc.hosts()
    assert agg.hosts("alpha.1") == inproc.hosts("alpha.1")
    assert [encode_line(r) for r in agg.records] == \
        [encode_line(r) for r in inproc.records]
    got = [encode_line(r) for r in agg.select(job="beta.2", kind="perf")]
    want = [encode_line(r) for r in inproc.select(job="beta.2",
                                                  kind="perf")]
    assert got == want
    a = inproc.scan(kind="perf", fields=("gflops", "step"))
    b = agg.scan(kind="perf", fields=("gflops", "step"))
    assert a.n == b.n

    def key_set(sc):
        v, p = sc.field("gflops")
        return sorted(
            (float(t), str(sc.host_vocab[h]),
             float(v[i]) if p[i] and not np.isnan(v[i]) else None)
            for i, (t, h) in enumerate(zip(sc.ts, sc.host_codes)))
    assert key_set(a) == key_set(b)


def test_remote_scatter_overlaps_transport(remote_pair):
    """The scatter path must issue every shard request before consuming
    any reply — transport overlaps with worker compute."""
    _inproc, agg = remote_pair
    query(agg, FLEET_Q)
    stats = agg.last_query_stats
    assert stats["mode"] == "scatter_gather" and stats["remote"]
    assert stats["overlap"] is True
    sends = [j for j, (k, _) in enumerate(agg.last_io_trace) if k == "send"]
    recvs = [j for j, (k, _) in enumerate(agg.last_io_trace) if k == "recv"]
    assert len(sends) == len(recvs) == agg.num_shards
    assert max(sends) < min(recvs)


def test_remote_warm_path_uses_worker_caches(remote_pair):
    """Workers consult their own segment-keyed partial caches, and an
    unchanged worker short-circuits the whole exchange with a
    conditional-scatter ``not_modified`` reply."""
    inproc, agg = remote_pair
    first = query(agg, FLEET_Q)
    # identical store: every worker answers not_modified from its etag
    rows_identical(query(agg, FLEET_Q), first, FLEET_Q)
    stats = agg.last_query_stats
    assert stats["segments_computed"] == 0
    assert stats["shards_unchanged"] == agg.num_shards
    ex = agg.explain(FLEET_Q)
    assert ex["mode"] == "scatter_gather" and ex["remote"]
    assert ex["segments"]["sealed"] > 0
    assert ex["segments"]["cached"] == ex["segments"]["sealed"]
    assert all(w["alive"] for w in ex["workers"])
    # new data: only the touched shard recomputes, and only its buffer
    extra = MetricRecord(9999.0, "n0", "alpha.1", "perf", {"gflops": 50.0})
    assert agg.insert(extra) and inproc.insert(extra)
    rows_identical(query(agg, FLEET_Q), query(inproc, FLEET_Q), FLEET_Q)
    stats = agg.last_query_stats
    assert stats["segments_computed"] == 0
    assert stats["shards_unchanged"] == agg.num_shards - 1
    assert agg.partial_cache_hits > 0


def test_remote_dedup_matches_inprocess(remote_pair):
    inproc, agg = remote_pair
    before = agg.duplicates_dropped
    for rec in RECORDS[::40]:  # at-least-once retransmits
        assert not agg.insert(rec)
        assert not inproc.insert(rec)
    assert agg.duplicates_dropped - before == len(RECORDS[::40])
    assert len(agg) == len(inproc)


# ===========================================================================
# Restart + degraded mode (acceptance)
# ===========================================================================

@pytest.fixture()
def fleet2(tmp_path):
    agg = make_remote(tmp_path / "fleet", 2)
    yield agg
    agg.close()


SWEEP = [FLEET_Q,
         "stats stdev(gflops) range(gflops) dc(host) dc(app) by kind",
         "stats median(gflops) p25(gflops) p90(gflops) by job",
         "search kind=perf | stats first(app) last(gflops)",  # exact gather
         "search kind=perf | sort -gflops | head 7",
         "dedup job app"]


def test_remote_parity_after_worker_restart(fleet2):
    inproc = random_store(records=RECORDS, shards=2, seal_threshold=SEAL)
    want = {q: query(inproc, q) for q in SWEEP}
    agg = fleet2
    agg.kill_worker(0)
    agg.restart_worker(0)  # fresh process re-adopts the durable dir
    assert all(agg.workers_alive())
    for q in SWEEP:
        rows_identical(query(agg, q), want[q], q)
    assert agg.last_query_stats["degraded_shards"] == 0
    # dedup keys survived the restart (segments + WAL replay)
    assert not agg.insert(RECORDS[0])


def test_remote_degraded_dead_worker_falls_back_locally(fleet2):
    inproc = random_store(records=RECORDS, shards=2, seal_threshold=SEAL)
    agg = fleet2
    want = {q: query(inproc, q) for q in SWEEP}
    agg.kill_worker(1)
    for q in SWEEP:
        rows_identical(query(agg, q), want[q], q)
        assert agg.last_query_stats["degraded_shards"] == 1, q
    assert agg.degraded_queries >= len(SWEEP)
    assert agg.shards[1].degraded_calls > 0
    ex = agg.explain(FLEET_Q)
    assert ex["degraded_shards"] == 1
    assert [w["alive"] for w in ex["workers"]] == [True, False]
    assert agg.workers_alive() == [True, False]
    # the store surface degrades too (dashboards keep rendering)
    assert agg.jobs() == inproc.jobs()
    assert len(agg) == len(inproc)
    # a restart brings the shard back out of degraded mode
    agg.restart_worker(1)
    for q in SWEEP[:2]:
        rows_identical(query(agg, q), want[q], q)
        assert agg.last_query_stats["degraded_shards"] == 0


def test_remote_degraded_disabled_raises(tmp_path):
    """degraded_ok=False covers the *whole* store surface, not just
    query(): scan/records/vocabs must refuse to serve stale read-only
    snapshots too."""
    agg = RemoteShardedAggregator(num_shards=2, directory=tmp_path / "f",
                                  seal_threshold=SEAL, degraded_ok=False,
                                  worker_idle_timeout_s=IDLE_S)
    try:
        for rec in RECORDS[:40]:
            agg.insert(rec)
        agg.kill_worker(0)
        with pytest.raises(rm.WorkerUnavailable):
            query(agg, FLEET_Q)
        with pytest.raises(rm.WorkerUnavailable):
            agg.scan(kind="perf", fields=("gflops",))
        with pytest.raises(rm.WorkerUnavailable):
            agg.jobs()
        with pytest.raises(rm.WorkerUnavailable):
            agg.records
    finally:
        agg.close()


def test_remote_reply_streams_resync_after_midmerge_error(tmp_path):
    """Regression: an error raised part-way through the reply-merge
    loop (here: degraded execution disabled + a dead worker) must not
    leave other workers' replies buffered on their sockets — a later
    query would consume a stale frame as its own answer and serve
    wrong results forever.  The affected connections are dropped and
    reconnect on the next send."""
    agg = RemoteShardedAggregator(num_shards=2, directory=tmp_path / "f",
                                  seal_threshold=SEAL, degraded_ok=False,
                                  worker_idle_timeout_s=IDLE_S)
    try:
        for rec in RECORDS[:80]:
            agg.insert(rec)
        want = query(agg, FLEET_Q)
        agg.kill_worker(0)
        with pytest.raises(rm.WorkerUnavailable):
            query(agg, FLEET_Q)  # worker 1's reply must not linger
        agg.restart_worker(0)
        for _ in range(3):  # repeated queries stay in sync
            rows_identical(query(agg, FLEET_Q), want, FLEET_Q)
            assert agg.last_query_stats["degraded_shards"] == 0
    finally:
        agg.close()


def test_close_leaves_externally_managed_workers_running(tmp_path):
    """A coordinator attached via addresses= does not own the workers:
    close() must detach without shutting the shared fleet down."""
    ext = LocalWorkerProcess(tmp_path / "f" / "shard-00",
                             seal_threshold=SEAL, idle_timeout_s=IDLE_S)
    try:
        agg = RemoteShardedAggregator(
            num_shards=1, directory=tmp_path / "f",
            seal_threshold=SEAL, addresses=[ext.address])
        assert agg.insert(RECORDS[0])
        agg.close()
        assert ext.alive  # still serving
        again = RemoteShardedAggregator(
            num_shards=1, directory=tmp_path / "f",
            seal_threshold=SEAL, addresses=[ext.address])
        assert len(again) == 1  # same worker, data intact
        again.close()
        assert ext.alive
    finally:
        ext.stop(timeout_s=5.0)


def test_remote_overlap_true_after_runtime_scatter_fallback(fleet2):
    """A plan that compiles but falls back at runtime re-runs as an
    exact gather; the overlap invariant is judged on the gather's own
    trace, not the aborted scatter's."""
    agg = fleet2
    q = "eval hot=gflops>750 | stats sum(hot) by job"  # bool eval
    inproc = random_store(records=RECORDS, shards=2, seal_threshold=SEAL)
    rows_identical(query(agg, q), query(inproc, q), q)
    stats = agg.last_query_stats
    assert stats["mode"] == "exact_gather"
    assert stats["overlap"] is True
    # the combined trace still records both phases for operators
    kinds = [k for k, _i in agg.last_io_trace]
    assert kinds.count("send") == 2 * agg.num_shards


def test_remote_bulk_ingest_lines_matches_per_record(fleet2):
    agg = fleet2
    extra = [MetricRecord(50000.0 + i, f"n{i % 4}", "bulk.1", "perf",
                          {"v": float(i)}) for i in range(20)]
    lines = [encode_line(r) for r in extra]
    assert agg.ingest_lines(lines) == 20
    assert agg.ingest_lines(lines) == 0  # dedup via the batched path
    rows = query(agg, "search job=bulk.1 | stats count sum(v)")
    assert rows == [{"count": 20, "sum_v": float(sum(range(20)))}]


def test_remote_adopt_store_dir_refused(fleet2, tmp_path):
    src = random_store(records=RECORDS[:30], directory=tmp_path / "src",
                       seal_threshold=10)
    src.close()
    with pytest.raises(RuntimeError, match="not supported"):
        fleet2.adopt_store_dir(tmp_path / "src")


def test_remote_constructor_misuse_rejected(tmp_path):
    with pytest.raises(ValueError, match="directory"):
        RemoteShardedAggregator(num_shards=2)
    with pytest.raises(ValueError, match="addresses"):
        RemoteShardedAggregator(num_shards=2, directory=tmp_path / "f",
                                spawn=False)
    with pytest.raises(ValueError, match="not both"):
        RemoteShardedAggregator(num_shards=1, directory=tmp_path / "f",
                                spawn=True, addresses=[("127.0.0.1", 1)])
    from repro.core.aggregator import Aggregator
    with pytest.raises(ValueError, match="shards"):
        Aggregator(tmp_path / "inbox", remote_workers=True,
                   store_dir=tmp_path / "f")


def test_remote_close_is_idempotent_and_guards_use(tmp_path):
    agg = make_remote(tmp_path / "fleet", 2, records=RECORDS[:60])
    procs = [sh.process for sh in agg.shards]
    agg.close()
    agg.close()
    assert all(not p.alive for p in procs)  # workers shut down
    with pytest.raises(RuntimeError, match="closed"):
        agg.query("stats count")
    with pytest.raises(RuntimeError, match="closed"):
        agg.insert(RECORDS[0])


# ===========================================================================
# Dashboards / detectors / streaming over the wire
# ===========================================================================

def _fill_dash(store):
    for h in range(3):
        for s in range(20):
            stalled = h == 2 and s > 10
            store.insert(MetricRecord(
                1000.0 + s * 10.0 + h * 0.1, f"n{h}", "jobA", "perf",
                {"gflops": 0.0 if stalled else 500.0, "mfu": 0.4,
                 "steps_per_s": 0.0 if stalled else 1.0, "step": s}))
            store.insert(MetricRecord(
                1000.0 + s * 10.0 + h * 0.1 + 0.01, f"n{h}", "jobA",
                "device", {"hbm_frac_used": 0.5, "local_devices": 4}))
    return store


def test_dashboards_and_detectors_identical_over_remote(tmp_path):
    from repro.core.aggregator import MetricStore
    from repro.core.daemon import JobManifest
    from repro.core.dashboards import (job_metric_series,
                                       job_statistical_view,
                                       view_idle_accelerators)
    from repro.core.detectors import DetectorBank
    single = _fill_dash(MetricStore(seal_threshold=16))
    agg = RemoteShardedAggregator(num_shards=2, directory=tmp_path / "d",
                                  seal_threshold=16,
                                  worker_idle_timeout_s=IDLE_S)
    try:
        _fill_dash(agg)
        assert job_metric_series(single, "jobA", "gflops") == \
            job_metric_series(agg, "jobA", "gflops")
        assert job_statistical_view(single, "jobA", "gflops") == \
            job_statistical_view(agg, "jobA", "gflops")
        assert_rows_equal(view_idle_accelerators(agg),
                          view_idle_accelerators(single), "idle_view")
        manifests = {"jobA": JobManifest(job_id="jobA", num_hosts=3)}
        key = lambda e: (e.detector, e.job,  # noqa: E731
                         sorted(e.fields.items()))
        assert sorted(map(key, DetectorBank().scan(single, manifests))) == \
            sorted(map(key, DetectorBank().scan(agg, manifests)))
    finally:
        agg.close()


def test_aggregator_watch_streams_over_remote_fleet(tmp_path):
    """`Aggregator(remote_workers=True)`: pump → watch refresh runs
    the scatter over worker processes, with partial updates flowing
    into the handle (QueryHandle.refresh is the consuming surface)."""
    from repro.core.aggregator import Aggregator

    def rec(ts, host, v):
        return MetricRecord(ts, host, "j1", "perf", {"v": v, "step": int(ts)})

    agg = Aggregator(tmp_path / "inbox", shards=2, remote_workers=True,
                     store_dir=tmp_path / "fleet")
    try:
        assert isinstance(agg.store, RemoteShardedAggregator)
        inbox = tmp_path / "inbox" / "a.log"
        lines = [encode_line(rec(1000.0 + i, f"n{i % 3}", float(i)))
                 for i in range(9)]
        inbox.write_text("".join(ln + "\n" for ln in lines))
        handle = agg.watch("stats sum(v) count by host")
        assert agg.pump() == 9
        rows = handle.refresh()
        assert sum(r["count"] for r in rows) == 9
        assert handle.refresh() is rows  # version-gated: no re-query
        inbox.write_text("".join(ln + "\n" for ln in lines) +
                         encode_line(rec(2000.0, "n9", 5.0)) + "\n")
        assert agg.pump() == 1  # replays dedup, the new line lands
        rows2 = agg.refresh_watches()["stats sum(v) count by host"]
        assert sum(r["count"] for r in rows2) == 10
    finally:
        agg.close()


# ===========================================================================
# Worker process / CLI lifecycle
# ===========================================================================

def test_worker_cli_serves_and_shuts_down(tmp_path):
    """The `repro-shard-worker` entry point (same `main` as `python -m
    repro.core.workers`): spawn, handshake, ingest, query ops, clean
    shutdown within a hard deadline."""
    proc = LocalWorkerProcess(tmp_path / "s0", seal_threshold=8,
                              idle_timeout_s=IDLE_S)
    try:
        client = WorkerClient(proc.address, op_timeout_s=20.0)
        hello = client.connect()
        assert hello["nrecords"] == 0 and hello["pid"] == proc.proc.pid
        line = encode_line(MetricRecord(1.0, "n0", "j", "perf", {"v": 2.0}))
        assert client.rpc("insert", line=line)["accepted"]
        assert not client.rpc("insert", line=line)["accepted"]  # dedup
        assert client.rpc("len")["n"] == 1
        assert client.rpc("dups")["n"] == 1
        assert client.rpc("vocab", which="jobs")["values"] == ["j"]
        bad = client.rpc("ping")  # unknown ops error without killing it
        assert bad["ok"]
        with pytest.raises(rm.WorkerError):
            client.rpc("no_such_op")
        with pytest.raises(rm.WorkerError):
            client.rpc("scatter", plan={"v": 999})  # malformed plan state
        assert client.rpc("ping")["ok"]  # connection survived the errors
        client.rpc("shutdown")
        client.close()
        proc.proc.wait(timeout=10)
        assert proc.proc.returncode == 0
    finally:
        proc.stop(timeout_s=5.0)


def test_worker_idle_timeout_self_exits(tmp_path):
    """Orphan protection: an unattended worker exits on its own, so a
    wedged coordinator cannot leak processes past CI's hard timeout."""
    proc = LocalWorkerProcess(tmp_path / "s0", idle_timeout_s=1.0)
    try:
        proc.proc.wait(timeout=20)
        assert proc.proc.returncode == 0
    finally:
        proc.stop(timeout_s=5.0)


def test_worker_version_mismatch_refused(tmp_path, monkeypatch):
    proc = LocalWorkerProcess(tmp_path / "s0", idle_timeout_s=IDLE_S)
    try:
        client = WorkerClient(proc.address, op_timeout_s=20.0)
        monkeypatch.setattr(rm, "PROTOCOL_VERSION", 999)
        with pytest.raises((rm.WorkerError, rm.RemoteProtocolError)):
            client.connect()
        client.close()
    finally:
        proc.stop(timeout_s=5.0)


def test_worker_topology_recorded_in_manifest(tmp_path):
    from repro.core import segmentio
    agg = make_remote(tmp_path / "fleet", 2, records=RECORDS[:10])
    try:
        man = segmentio.load_shardset_manifest(tmp_path / "fleet")
        workers = man["workers"]
        assert [w["shard"] for w in workers] == [0, 1]
        assert all(w["pid"] and w["port"] for w in workers)
        with pytest.raises(ValueError):
            segmentio.update_shardset_manifest(tmp_path / "fleet",
                                               {"num_shards": 7})
    finally:
        agg.close()


# ===========================================================================
# Read-only store opens (the degraded-mode primitive)
# ===========================================================================

def test_read_only_store_open_is_side_effect_free(tmp_path):
    live = random_store(records=RECORDS[:120], seal_threshold=29,
                        directory=tmp_path / "s")
    want = query(live, FLEET_Q)
    live.close()
    wal_before = (tmp_path / "s" / "wal.log").read_bytes()
    ro = ColumnarMetricStore(directory=tmp_path / "s", seal_threshold=29,
                             read_only=True)
    rows_identical(query(ro, FLEET_Q), want, FLEET_Q)
    with pytest.raises(RuntimeError, match="read-only"):
        ro.insert(RECORDS[0])
    with pytest.raises(RuntimeError, match="read-only"):
        ro.seal()
    ro.close()
    # nothing on disk moved: the WAL was replayed, never rewritten
    assert (tmp_path / "s" / "wal.log").read_bytes() == wal_before
    # and the real owner can still open the directory normally
    back = ColumnarMetricStore(directory=tmp_path / "s", seal_threshold=29)
    rows_identical(query(back, FLEET_Q), want, FLEET_Q)
    back.close()
    with pytest.raises(ValueError):
        ColumnarMetricStore(read_only=True)  # requires a directory


# ===========================================================================
# Worker liveness + connection-pool hygiene (ISSUE 8 satellites)
# ===========================================================================

class _SlowOpWorker(__import__("repro.core.workers",
                               fromlist=["ShardWorker"]).ShardWorker):
    """In-process worker with an op that outlives the idle timeout."""

    def _op_slow(self, msg):
        import time as _t
        _t.sleep(float(msg.get("s", 1.0)))
        return {}


def _serve_inproc(worker):
    import threading
    t = threading.Thread(target=worker.serve_forever, daemon=True)
    t.start()
    return t


def test_worker_idle_timer_gated_by_inflight_requests(tmp_path):
    """Regression: the accept loop's idle check used to fire while a
    connection thread was still inside handle(), killing the worker
    mid-request.  Idle only counts while nothing is in flight — a
    handler slower than the timeout survives, and the timer restarts
    from the reply."""
    worker = _SlowOpWorker(tmp_path / "s0", idle_timeout_s=0.6)
    t = _serve_inproc(worker)
    client = WorkerClient(worker.address, op_timeout_s=20.0)
    client.connect()
    assert client.rpc("slow", s=1.5)["ok"]  # 2.5x the idle timeout
    assert t.is_alive()  # the worker did not die under the request
    assert client.rpc("ping")["ok"]  # and still serves
    client.close()
    t.join(timeout=20.0)  # true idleness still self-exits
    assert not t.is_alive()


def test_worker_request_counters_exact_under_concurrency(tmp_path):
    """Regression: ``requests_served``/``_last_activity`` are mutated
    from every per-connection thread; without the stats lock the +=
    lost updates and the counter lied.  Exact count asserted across
    overlapped connections."""
    import threading
    worker = _SlowOpWorker(tmp_path / "s0", idle_timeout_s=IDLE_S)
    t = _serve_inproc(worker)
    n_threads, n_pings = 8, 25
    errs = []

    def hammer():
        try:
            c = WorkerClient(worker.address, op_timeout_s=20.0)
            c.connect()  # hello: 1 request
            for _ in range(n_pings):
                assert c.rpc("ping")["ok"]
            c.close()
        except Exception as exc:  # noqa: BLE001 - surfaced below
            errs.append(exc)

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=30.0)
    assert not errs
    assert worker.requests_served == n_threads * (1 + n_pings)
    assert worker._inflight == 0
    worker._shutdown = True
    t.join(timeout=10.0)


def test_kill_worker_drains_checked_out_connections(tmp_path):
    """Regression: kill_worker never drained pooled connections that
    were checked out mid-flight — release() happily re-pooled them
    after the teardown, leaking one socket per kill/restart cycle.
    The pool generation closes them on release instead."""
    agg = make_remote(tmp_path / "fleet", 2, records=RECORDS[:60])
    try:
        sh = agg.shards[0]
        c1 = sh.acquire()           # the primary client
        c2 = sh.acquire()           # a fresh mid-flight connection
        assert c1 is sh.client and c2 is not sh.client
        agg.kill_worker(0)
        sh.release(c1)
        sh.release(c2)              # stale generation: closed, not pooled
        assert sh._idle == []
        assert not c2.connected
        agg.restart_worker(0)
        assert sh.ping()
    finally:
        agg.close()


def test_kill_restart_cycles_do_not_leak_fds(tmp_path):
    """Five kill/restart cycles with connections checked out mid-kill:
    the process fd count must stay flat (the pre-fix leak grew by one
    pooled socket per cycle)."""
    import gc
    import os as _os

    def fd_count():
        gc.collect()
        return len(_os.listdir("/proc/self/fd"))

    inproc = random_store(records=RECORDS[:80], shards=2,
                          seal_threshold=SEAL)
    agg = make_remote(tmp_path / "fleet", 2, records=RECORDS[:80])
    try:
        want = query(inproc, FLEET_Q)
        rows_identical(query(agg, FLEET_Q), want, FLEET_Q)
        sh = agg.shards[0]
        base = fd_count()
        for _ in range(5):
            c1 = sh.acquire()
            c2 = sh.acquire()
            agg.kill_worker(0)
            sh.release(c1)
            sh.release(c2)
            assert sh._idle == []
            agg.restart_worker(0)
            rows_identical(query(agg, FLEET_Q), want, FLEET_Q)
            assert agg.last_query_stats["degraded_shards"] == 0
        assert fd_count() <= base + 3
    finally:
        agg.close()
        inproc.close()


def test_replicated_parity_with_member_killed_mid_scatter(tmp_path):
    """Parity-sweep extension (acceptance): on a replicated fleet with
    one member killed while scatters are in flight, every sweep query
    stays byte-identical to the in-process sharded oracle and no shard
    enters degraded mode."""
    import threading
    inproc = random_store(records=RECORDS, shards=2, seal_threshold=SEAL)
    agg = RemoteShardedAggregator(num_shards=2, directory=tmp_path / "f",
                                  seal_threshold=SEAL, replicas=2,
                                  hedge_delay_s=0.02,
                                  worker_idle_timeout_s=IDLE_S)
    try:
        for rec in RECORDS:
            agg.insert(rec)
        agg.sync_replicas()
        want = {q: query(inproc, q) for q in SWEEP}
        sh = agg.shards[0]
        slow = sh._read_order()[0]
        slow.rpc("set_delay", s=0.5)
        agg.drop_scatter_memos()
        member = sh.members.index(slow)
        timer = threading.Timer(
            0.1, lambda: agg.kill_worker(0, member=member))
        timer.start()
        try:
            for q in SWEEP:
                rows_identical(query(agg, q), want[q], q)
                assert agg.last_query_stats["degraded_shards"] == 0, q
        finally:
            timer.join()
        # catch-up: the killed member restarts and converges to the
        # primary's exact version tuple
        agg.restart_worker(0, member=member)
        agg.sync_replicas()
        versions = {tuple(m._version()) for m in sh.members}
        assert len(versions) == 1
    finally:
        agg.close()
        inproc.close()
