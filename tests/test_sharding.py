"""Sharding rules: logical-axis resolution, divisibility fallbacks,
param-path pattern rules, duplicate-axis exclusion."""

import os

import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.train.sharding import (DEFAULT_RULES, ShardingCtx, param_logical,
                                  param_specs)


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import _mk
    # single real device: a 1x1 mesh still exercises the resolution code
    return _mk((1, 1), ("data", "model"))


def test_param_logical_rules():
    assert param_logical("embed/table", 2) == ("vocab", "fsdp")
    assert param_logical("blocks/attn/wq", 4) == (None, "fsdp", "heads",
                                                  None)
    assert param_logical("blocks/attn/wq", 3) == ("fsdp", "heads", None)
    assert param_logical("blocks/mlp/w_down", 3) == (None, "d_ff", "fsdp")
    assert param_logical("blocks/moe/w_gate", 4) == (None, "experts",
                                                     "fsdp", None)
    assert param_logical("blocks/ssm/in_proj", 3) == (None, "fsdp",
                                                      "inner")
    assert param_logical("final_norm_scale", 1) == (None,)
    assert param_logical("blocks/attn/norm_scale", 2) == (None, None)


def test_spec_divisibility_fallback(mesh):
    ctx = ShardingCtx(mesh=mesh)
    # axis size 1 always divides -> mapped; verify structure not crash
    spec = ctx.spec(("batch", None, "heads"), (8, 16, 4))
    assert isinstance(spec, P)


def test_spec_no_duplicate_mesh_axes():
    from repro.launch.mesh import _mk
    mesh = _mk((1, 1), ("data", "model"))
    ctx = ShardingCtx(mesh=mesh).with_rules(seq=("model",))
    # heads also wants "model": only one dim may take it
    spec = ctx.spec(("batch", "seq", "heads"), (8, 16, 4))
    axes = [a for part in spec for a in
            (part if isinstance(part, tuple) else (part,)) if a]
    assert len(axes) == len(set(axes))


def test_param_specs_tree_structure(mesh):
    import jax.numpy as jnp
    ctx = ShardingCtx(mesh=mesh)
    params = {"embed": {"table": jnp.zeros((8, 4))},
              "blocks": {"attn": {"wq": jnp.zeros((2, 4, 2, 2))}}}
    specs = param_specs(params, ctx)
    assert isinstance(specs["embed"]["table"], P)
    assert isinstance(specs["blocks"]["attn"]["wq"], P)


def test_null_ctx_act_is_noop():
    import jax.numpy as jnp
    ctx = ShardingCtx(mesh=None)
    x = jnp.ones((4, 4))
    assert ctx.act(x, "batch", "embed") is x


def test_rules_override():
    ctx = ShardingCtx(mesh=None).with_rules(seq=("model",))
    assert ctx.rules["seq"] == ("model",)
    assert ctx.rules["batch"] == DEFAULT_RULES["batch"]
