"""Tiny fallback shim for ``hypothesis`` so property tests still run
(with deterministic pseudo-random examples) when the real library is not
installed.  Installed into ``sys.modules`` by ``conftest.py`` only when
``import hypothesis`` fails; implements just the strategy surface this
test suite uses.
"""

from __future__ import annotations

import random
import re
import string
import sys
import types
import zlib

_MAX_EXAMPLES_CAP = 10  # keep the fallback suite fast


class Unsatisfied(Exception):
    pass


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise Unsatisfied("filter predicate too strict for shim")
        return Strategy(draw)

    def map(self, fn):
        return Strategy(lambda rng: fn(self._draw(rng)))


def integers(min_value=None, max_value=None):
    lo = -(2 ** 31) if min_value is None else int(min_value)
    hi = 2 ** 31 if max_value is None else int(max_value)

    def draw(rng):
        if rng.random() < 0.2:  # edge bias
            return rng.choice([lo, hi, 0 if lo <= 0 <= hi else lo])
        return rng.randint(lo, hi)
    return Strategy(draw)


def floats(min_value=None, max_value=None, allow_nan=None,
           allow_infinity=None, width=64):
    def draw(rng):
        if min_value is not None or max_value is not None:
            lo = -1e9 if min_value is None else float(min_value)
            hi = 1e9 if max_value is None else float(max_value)
            if rng.random() < 0.15:
                return rng.choice([lo, hi, (lo + hi) / 2.0])
            return rng.uniform(lo, hi)
        r = rng.random()
        if r < 0.1:
            return rng.choice([0.0, 1.0, -1.0, 0.5, 1e-9, 1e12, -3.25])
        # log-uniform magnitudes, both signs
        mag = 10.0 ** rng.uniform(-12, 12)
        return mag if rng.random() < 0.5 else -mag
    return Strategy(draw)


def booleans():
    return Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(seq):
    seq = list(seq)
    return Strategy(lambda rng: rng.choice(seq))


def just(value):
    return Strategy(lambda rng: value)


def one_of(*strategies):
    return Strategy(lambda rng: rng.choice(strategies).example(rng))


def lists(elements, min_size=0, max_size=None):
    hi = (min_size + 12) if max_size is None else max_size

    def draw(rng):
        n = rng.randint(min_size, hi)
        return [elements.example(rng) for _ in range(n)]
    return Strategy(draw)


_TEXT_ALPHABET = (string.ascii_letters + string.digits
                  + " .,:;!?_-+*/=()[]{}'\"\\%&#@^~$|<>\n\t"
                  + "äöüßéλΩ中日")


def text(alphabet=None, min_size=0, max_size=20):
    chars = list(alphabet) if alphabet else list(_TEXT_ALPHABET)

    def draw(rng):
        n = rng.randint(min_size, max_size)
        return "".join(rng.choice(chars) for _ in range(n))
    return Strategy(draw)


def dictionaries(keys, values, min_size=0, max_size=8):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        out = {}
        for _ in range(n * 3):
            if len(out) >= n:
                break
            try:
                k = keys.example(rng)
            except Unsatisfied:
                continue
            if k not in out:
                out[k] = values.example(rng)
        return out
    return Strategy(draw)


def tuples(*strategies):
    return Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


# ------------------------------------------------------------- from_regex ---
# Minimal generator for the simple patterns this suite uses:
# sequences of literals / [character classes] with optional {m,n} bounds.

_CLASS_RE = re.compile(
    r"\[([^\]]+)\](?:\{(\d+)(?:,(\d+))?\})?|(\\?.)(?:\{(\d+)(?:,(\d+))?\})?")


def _expand_class(body: str) -> str:
    chars = []
    i = 0
    while i < len(body):
        c = body[i]
        if c == "\\" and i + 1 < len(body):
            chars.append(body[i + 1])
            i += 2
            continue
        if i + 2 < len(body) and body[i + 1] == "-":
            for o in range(ord(c), ord(body[i + 2]) + 1):
                chars.append(chr(o))
            i += 3
            continue
        chars.append(c)
        i += 1
    return "".join(chars)


def from_regex(pattern, fullmatch=False):
    if hasattr(pattern, "pattern"):
        pattern = pattern.pattern
    tokens = []
    pos = 0
    while pos < len(pattern):
        m = _CLASS_RE.match(pattern, pos)
        if m is None:  # pragma: no cover - unsupported pattern
            raise NotImplementedError(f"shim from_regex: {pattern!r}")
        pos = m.end()
        if m.group(1) is not None:
            chars = _expand_class(m.group(1))
            lo = int(m.group(2)) if m.group(2) else 1
            hi = int(m.group(3)) if m.group(3) else lo
        else:
            lit = m.group(4)
            chars = lit[-1]
            lo = int(m.group(5)) if m.group(5) else 1
            hi = int(m.group(6)) if m.group(6) else lo
        tokens.append((chars, lo, hi))
    compiled = re.compile(pattern)

    def draw(rng):
        for _ in range(100):
            parts = []
            for chars, lo, hi in tokens:
                n = rng.randint(lo, hi)
                parts.append("".join(rng.choice(chars) for _ in range(n)))
            s = "".join(parts)
            if compiled.fullmatch(s):
                return s
        raise Unsatisfied(f"cannot satisfy {pattern!r}")
    return Strategy(draw)


# ------------------------------------------------------- given / settings ---

def settings(*args, **kwargs):
    def deco(fn):
        fn._shim_settings = kwargs
        return fn
    if args and callable(args[0]):  # bare @settings
        return args[0]
    return deco


def assume(condition):
    if not condition:
        raise Unsatisfied("assumption failed")
    return True


def given(*gargs, **gkwargs):
    def deco(fn):
        cfg = getattr(fn, "_shim_settings", {})

        def wrapper():
            n = min(int(cfg.get("max_examples", _MAX_EXAMPLES_CAP)),
                    _MAX_EXAMPLES_CAP)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(max(n, 1)):
                try:
                    args = [s.example(rng) for s in gargs]
                    kwargs = {k: s.example(rng) for k, s in gkwargs.items()}
                except Unsatisfied:
                    continue
                try:
                    fn(*args, **kwargs)
                except Unsatisfied:
                    continue

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper._shim_settings = cfg
        return wrapper
    return deco


def install() -> None:
    """Register the shim as ``hypothesis`` / ``hypothesis.strategies``."""
    hyp = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "just",
                 "one_of", "lists", "text", "dictionaries", "tuples",
                 "from_regex"):
        setattr(st_mod, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = st_mod
    hyp.__version__ = "0.0-shim"
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
