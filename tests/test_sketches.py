"""Streaming-sketch accuracy: P2 quantiles vs exact, Welford vs numpy."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.sketches import (P2Quantile, QuantileSet, StreamStats,
                                 exact_quantile)


def test_stream_stats_matches_numpy():
    rng = np.random.default_rng(0)
    xs = rng.standard_normal(1000) * 3 + 7
    s = StreamStats().extend(xs)
    assert np.isclose(s.mean, xs.mean())
    assert np.isclose(s.std, xs.std(ddof=0), rtol=1e-6)
    assert s.min == xs.min() and s.max == xs.max()
    assert s.n == 1000


def test_stream_stats_merge():
    rng = np.random.default_rng(1)
    a, b = rng.standard_normal(500), rng.standard_normal(300) + 2
    sa = StreamStats().extend(a)
    sb = StreamStats().extend(b)
    sa.merge(sb)
    xs = np.concatenate([a, b])
    assert np.isclose(sa.mean, xs.mean())
    assert np.isclose(sa.var, xs.var(ddof=0), rtol=1e-6)


def test_p2_median_normal():
    rng = np.random.default_rng(2)
    xs = rng.standard_normal(5000)
    q = P2Quantile(0.5)
    for x in xs:
        q.add(x)
    assert abs(q.value - np.median(xs)) < 0.05


def test_p2_small_stream_exact():
    q = P2Quantile(0.5)
    for x in [3.0, 1.0, 2.0]:
        q.add(x)
    assert q.value == 2.0


@given(st.lists(st.floats(min_value=-1e6, max_value=1e6), min_size=20,
                max_size=500),
       st.sampled_from([0.25, 0.5, 0.75, 0.9]))
@settings(max_examples=80, deadline=None)
def test_p2_bounded_error_property(xs, p):
    q = P2Quantile(p)
    for x in xs:
        q.add(x)
    exact = exact_quantile(xs, p)
    spread = max(xs) - min(xs)
    # P2 stays within the sample range and within a loose fraction of
    # the spread (it is an estimator, not exact)
    assert min(xs) - 1e-9 <= q.value <= max(xs) + 1e-9
    if spread > 0:
        assert abs(q.value - exact) <= 0.35 * spread + 1e-6


def test_quantile_set_summary():
    qs = QuantileSet()
    xs = list(range(101))
    for x in xs:
        qs.add(float(x))
    s = qs.summary()
    assert s["min"] == 0 and s["max"] == 100 and s["count"] == 101
    assert abs(s["median"] - 50) < 5
    assert abs(s["mean"] - 50) < 1e-9
