"""Streaming-sketch accuracy: P2 quantiles vs exact, Welford vs numpy,
and the mergeable P² summary algebra used by shard fan-out."""

import math
import random

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.sketches import (P2Quantile, P2Summary, QuantileSet,
                                 StreamStats, exact_quantile,
                                 merge_quantile_summaries)


def test_stream_stats_matches_numpy():
    rng = np.random.default_rng(0)
    xs = rng.standard_normal(1000) * 3 + 7
    s = StreamStats().extend(xs)
    assert np.isclose(s.mean, xs.mean())
    assert np.isclose(s.std, xs.std(ddof=0), rtol=1e-6)
    assert s.min == xs.min() and s.max == xs.max()
    assert s.n == 1000


def test_stream_stats_merge():
    rng = np.random.default_rng(1)
    a, b = rng.standard_normal(500), rng.standard_normal(300) + 2
    sa = StreamStats().extend(a)
    sb = StreamStats().extend(b)
    sa.merge(sb)
    xs = np.concatenate([a, b])
    assert np.isclose(sa.mean, xs.mean())
    assert np.isclose(sa.var, xs.var(ddof=0), rtol=1e-6)


def test_p2_median_normal():
    rng = np.random.default_rng(2)
    xs = rng.standard_normal(5000)
    q = P2Quantile(0.5)
    for x in xs:
        q.add(x)
    assert abs(q.value - np.median(xs)) < 0.05


def test_p2_small_stream_exact():
    q = P2Quantile(0.5)
    for x in [3.0, 1.0, 2.0]:
        q.add(x)
    assert q.value == 2.0


@given(st.lists(st.floats(min_value=-1e6, max_value=1e6), min_size=20,
                max_size=500),
       st.sampled_from([0.25, 0.5, 0.75, 0.9]))
@settings(max_examples=80, deadline=None)
def test_p2_bounded_error_property(xs, p):
    q = P2Quantile(p)
    for x in xs:
        q.add(x)
    exact = exact_quantile(xs, p)
    spread = max(xs) - min(xs)
    # P2 stays within the sample range and within a loose fraction of
    # the spread (it is an estimator, not exact)
    assert min(xs) - 1e-9 <= q.value <= max(xs) + 1e-9
    if spread > 0:
        assert abs(q.value - exact) <= 0.35 * spread + 1e-6


# ------------------------------------------------------- mergeable P² ------

def _eq_or_both_nan(a, b):
    return (math.isnan(a) and math.isnan(b)) or a == b


@given(st.lists(st.lists(st.floats(min_value=-1e6, max_value=1e6),
                         min_size=0, max_size=200),
                min_size=1, max_size=6),
       st.sampled_from([0.25, 0.5, 0.9, 0.95]),
       st.integers(min_value=0, max_value=10**6))
@settings(max_examples=60, deadline=None)
def test_p2_merge_order_insensitive(shards, p, permseed):
    """Merging shard summaries in any permutation yields the *same*
    estimate — required for a deterministic gather over async shards."""
    summaries = [P2Summary.from_values(xs, p) for xs in shards]
    merged = merge_quantile_summaries(summaries, p)
    perm = list(summaries)
    random.Random(permseed).shuffle(perm)
    assert _eq_or_both_nan(merge_quantile_summaries(perm, p), merged)
    allv = [x for xs in shards for x in xs]
    if allv:
        assert min(allv) - 1e-9 <= merged <= max(allv) + 1e-9
    else:
        assert math.isnan(merged)


@given(st.lists(st.floats(min_value=-1e6, max_value=1e6), min_size=0,
                max_size=300),
       st.sampled_from([0.25, 0.5, 0.75, 0.9]))
@settings(max_examples=40, deadline=None)
def test_p2_merge_empty_is_identity(xs, p):
    """merge(empty, s) == s — empty shards cannot move an estimate."""
    s = P2Summary.from_values(xs, p)
    empty = P2Summary.from_values([], p)
    want = s.point
    assert _eq_or_both_nan(merge_quantile_summaries([empty, s], p), want)
    assert _eq_or_both_nan(merge_quantile_summaries([s, empty], p), want)
    assert math.isnan(merge_quantile_summaries([empty, empty], p))


def test_p2_merge_small_shards_exact():
    # every shard below RAW_MAX keeps raw samples: the merge pools them
    # and is *exact*, not just bounded
    shards = [[5.0, 1.0], [2.0], [], [9.0, 3.0, 7.0]]
    allv = [x for xs in shards for x in xs]
    for p in (0.1, 0.5, 0.9):
        merged = merge_quantile_summaries(
            [P2Summary.from_values(xs, p) for xs in shards], p)
        assert merged == exact_quantile(allv, p)


def test_p2_merge_bounded_error_vs_exact():
    """The documented bound: merged estimate within the global value
    range and within 0.35·spread of the exact quantile (same bound the
    single-sketch property test uses)."""
    rng = np.random.default_rng(7)
    for p in (0.5, 0.9, 0.95):
        for dist in ("uniform", "normal", "lognormal"):
            xs = getattr(rng, dist)(size=4000)
            shards = np.array_split(rng.permutation(xs), 5)
            merged = merge_quantile_summaries(
                [P2Summary.from_values(s, p) for s in shards], p)
            exact = exact_quantile(xs.tolist(), p)
            spread = float(xs.max() - xs.min())
            assert xs.min() - 1e-9 <= merged <= xs.max() + 1e-9
            assert abs(merged - exact) <= 0.35 * spread + 1e-6
            # batch-built shard summaries have exact local knots, so in
            # practice the merge lands far inside the bound
            assert abs(merged - exact) <= 0.05 * spread + 1e-6


def test_p2_streamed_summary_merges_with_batch_summaries():
    rng = np.random.default_rng(11)
    xs = rng.normal(size=3000)
    a, b = xs[:1500], xs[1500:]
    stream = P2Quantile(0.5)
    for x in a:
        stream.add(float(x))
    merged = merge_quantile_summaries(
        [stream.summary(), P2Summary.from_values(b, 0.5)], 0.5)
    exact = exact_quantile(xs.tolist(), 0.5)
    spread = float(xs.max() - xs.min())
    assert abs(merged - exact) <= 0.1 * spread


def test_quantile_set_summary():
    qs = QuantileSet()
    xs = list(range(101))
    for x in xs:
        qs.add(float(x))
    s = qs.summary()
    assert s["min"] == 0 and s["max"] == 100 and s["count"] == 101
    assert abs(s["median"] - 50) < 5
    assert abs(s["mean"] - 50) < 1e-9
