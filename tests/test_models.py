"""Per-architecture smoke tests (reduced configs): one forward/train step
on CPU asserting output shapes + no NaNs, plus prefill/decode consistency
and Pallas-path parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, reduced
from repro.models import Model, ModelOptions, make_batch

OPTS = ModelOptions(remat_policy="none", attn_chunk=16, moe_group_size=32)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(aid):
        if aid not in cache:
            cfg = reduced(get_arch(aid))
            model = Model(cfg, options=OPTS)
            params = model.init(jax.random.PRNGKey(0))
            batch = make_batch(cfg, seq_len=32, batch=2, kind="train")
            cache[aid] = (cfg, model, params, batch)
        return cache[aid]
    return get


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_arch_forward_shapes_and_finite(built, aid):
    cfg, model, params, batch = built(aid)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    if cfg.is_moe:
        assert "moe_lb_loss" in aux


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_arch_train_step_no_nans(built, aid):
    from repro.optim import AdamW, OptimizerConfig
    from repro.train import StepConfig, make_train_step
    cfg, model, params, batch = built(aid)
    opt = AdamW(OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=10))
    state = opt.init(params)
    step = jax.jit(make_train_step(model, opt, StepConfig()))
    params2, state2, _, metrics = step(params, state, None, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.count) == 1
    # params actually changed
    a = jax.tree_util.tree_leaves(params)[3]
    b = jax.tree_util.tree_leaves(params2)[3]
    assert not np.allclose(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_arch_prefill_matches_forward(built, aid):
    cfg, model, params, batch = built(aid)
    logits, _ = jax.jit(model.forward)(params, batch)
    plogits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, extra_slots=4))(params, batch)
    err = float(jnp.max(jnp.abs(plogits[:, 0] - logits[:, -1])))
    assert err < 2e-2, err
    assert int(cache["pos"]) == 32 + cfg.num_meta_tokens


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_arch_decode_step(built, aid):
    cfg, model, params, batch = built(aid)
    _, cache = jax.jit(
        lambda p, b: model.prefill(p, b, extra_slots=4))(params, batch)
    if "tokens" in batch:
        db = {"tokens": batch["tokens"][:, -1:]}
    else:
        db = {"embeds": batch["embeds"][:, -1:]}
    dlogits, cache2 = jax.jit(model.decode_step)(params, db, cache)
    assert dlogits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(dlogits)))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("aid", ["gemma2-9b", "mamba2-780m", "hymba-1.5b",
                                 "qwen3-8b", "musicgen-medium"])
def test_pallas_path_parity(built, aid):
    cfg, model, params, batch = built(aid)
    m_p = Model(cfg, options=ModelOptions(remat_policy="none",
                                          attn_chunk=16, moe_group_size=32,
                                          use_pallas=True))
    lx, _ = jax.jit(model.forward)(params, batch)
    lp, _ = jax.jit(m_p.forward)(params, batch)
    assert float(jnp.max(jnp.abs(lx - lp))) < 5e-2


def test_decode_sequence_matches_forward():
    """Greedy decode token-by-token must agree with teacher-forced
    forward logits (qwen3 reduced)."""
    cfg = reduced(get_arch("qwen3-8b"))
    model = Model(cfg, options=OPTS)
    params = model.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, seq_len=16, batch=1, kind="train")
    logits, _ = jax.jit(model.forward)(params, batch)
    _, cache = jax.jit(
        lambda p, b: model.prefill(p, b, extra_slots=8))(
            params, {"tokens": batch["tokens"][:, :8]})
    decode = jax.jit(model.decode_step)
    for t in range(8, 12):
        dl, cache = decode(params, {"tokens": batch["tokens"][:, t:t + 1]},
                           cache)
        err = float(jnp.max(jnp.abs(dl[:, 0] - logits[:, t])))
        assert err < 2e-2, (t, err)


def test_mixed_window_layers_differ_from_global():
    """gemma's local layers must actually mask: compare against a config
    with all-global attention."""
    import dataclasses
    cfg = reduced(get_arch("gemma2-9b"))
    cfg_local = dataclasses.replace(cfg, window_size=4)
    cfg_global = dataclasses.replace(cfg, attn_pattern="global")
    batch = make_batch(cfg, seq_len=32, batch=1, kind="train")
    params = Model(cfg_local, options=OPTS).init(jax.random.PRNGKey(0))
    l1, _ = jax.jit(Model(cfg_local, options=OPTS).forward)(params, batch)
    l2, _ = jax.jit(Model(cfg_global, options=OPTS).forward)(params, batch)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-3
