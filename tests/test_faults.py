"""Fault injection + chaos parity (ISSUE 9 / docs/faults.md).

Acceptance contract: under randomized wire faults (drop / delay /
truncate / bit-flip), worker kills, and torn/ENOSPC seals, every query
against a replicated remote fleet is **byte-identical** to the
fault-free in-process oracle or fails with a *typed* error inside its
deadline budget — never a hang, never a silently wrong answer.  The
hardened pieces (frame checksums, WAL line checksums, retry with
idempotency keys, per-worker circuit breakers, corrupt-segment
quarantine) are unit-tested here with fake clocks and scripted fault
plans; the chaos suite then replays seeded randomized schedules over a
real fleet.
"""

import os
import socket
import struct
import time

import pytest

from conftest import random_records, random_store
from test_incremental import rows_identical

from repro.core import faults, remote as rm, segmentio, splunklite
from repro.core.columnar import ColumnarMetricStore
from repro.core.faults import (CircuitBreaker, FaultPlan, RetryPolicy,
                               RetryBudgetExceeded, crc32c)
from repro.core.remote import RemoteShardedAggregator
from repro.core.schema import MetricRecord
from repro.core.splunklite import QueryError, query

SEAL = 53
IDLE_S = 300.0  # workers self-exit if a wedged run leaks them
RECORDS = random_records(seed=9, n=420)

FLEET_Q = ("search kind=perf gflops>10 | stats avg(gflops) p90(gflops) "
           "count by job | sort -avg_gflops | head 10")

SWEEP = [FLEET_Q,
         "stats stdev(gflops) range(gflops) dc(host) dc(app) by kind",
         "stats median(gflops) p25(gflops) p90(gflops) by job",
         "search kind=perf | stats first(app) last(gflops)",  # exact gather
         "search kind=perf | sort -gflops | head 7",
         "dedup job app"]

#: the only acceptable failure modes under chaos — anything else
#: (KeyError from a half-decoded frame, struct.error, a wrong answer)
#: is a bug the hardening must have prevented
TYPED_ERRORS = (rm.WorkerUnavailable,     # + DeadlineExceeded, CircuitOpen
                rm.RemoteProtocolError,   # + FrameChecksumError
                rm.WorkerError, QueryError, TimeoutError)


@pytest.fixture()
def clean_storage_faults():
    yield
    faults.install_storage_faults(None)


def make_fleet(directory, n=2, replicas=2, records=RECORDS, **kw):
    agg = RemoteShardedAggregator(num_shards=n, directory=directory,
                                  seal_threshold=SEAL, replicas=replicas,
                                  worker_idle_timeout_s=IDLE_S,
                                  spawn_timeout_s=60.0, **kw)
    for rec in records:
        agg.insert(rec)
    return agg


# ===========================================================================
# crc32c + fault plans
# ===========================================================================

def test_crc32c_incremental_matches_one_shot():
    data = os.urandom(1 << 12)
    whole = crc32c(data)
    acc = 0
    for i in range(0, len(data), 100):
        acc = crc32c(data[i:i + 100], acc)
    assert acc == whole
    assert crc32c(b"") == 0
    assert faults.CRC_IMPL in ("crc32c", "crc32-zlib")


def test_fault_plan_is_deterministic_per_seed():
    def draws(seed):
        plan = FaultPlan(seed, rates={"send": {"drop": 0.2,
                                               "bitflip": 0.3}})
        return plan, [plan.draw("send") for _ in range(50)]

    a, seq = draws(7)
    _b, replay = draws(7)
    assert seq == replay  # same seed -> bit-for-bit the same schedule
    assert seq != draws(8)[1]  # seeds diverge
    assert a.injected_total() == sum(1 for k in seq if k is not None)


def test_forced_faults_fire_before_probabilistic_draws():
    plan = FaultPlan(0, rates={"seal": {"enospc": 1.0}})
    plan.force("seal", "torn_bin", times=2)
    assert [plan.draw("seal") for _ in range(3)] == \
        ["torn_bin", "torn_bin", "enospc"]
    assert plan.injected[("seal", "torn_bin")] == 2


def test_corrupt_flips_exactly_one_bit_past_skip():
    plan = FaultPlan(3)
    data = bytes(range(64))
    out = plan.corrupt(data, skip=4)
    assert out[:4] == data[:4] and len(out) == len(data)
    diff = [(a ^ b) for a, b in zip(out, data) if a != b]
    assert len(diff) == 1 and bin(diff[0]).count("1") == 1
    assert plan.corrupt(b"ab", skip=4) == b"ab"  # nothing past skip


# ===========================================================================
# Wire frames: crc32c trailers, oversized/garbage frames
# ===========================================================================

def _framed_pair():
    a, b = socket.socketpair()
    a.settimeout(5.0)
    b.settimeout(5.0)
    return a, b


def test_frame_checksum_round_trip_and_flag_interop():
    a, b = _framed_pair()
    try:
        rm.send_frame(a, {"op": "ping", "x": [1, 2.5, "s"]})
        assert rm.recv_frame(b) == {"op": "ping", "x": [1, 2.5, "s"]}
        # a peer with checksums disabled still interoperates: the flag
        # bit is per frame, absent means no trailer follows
        rm.send_frame(a, {"op": "ping"}, checksum=False)
        assert rm.recv_frame(b) == {"op": "ping"}
    finally:
        a.close()
        b.close()


def test_bit_flipped_payload_raises_frame_checksum_error():
    a, b = _framed_pair()
    try:
        payload = b'{"op": "ping"}'
        flipped = bytearray(payload)
        flipped[3] ^= 0x10
        a.sendall(struct.pack("!I", len(payload) | rm.FRAME_CRC_FLAG)
                  + bytes(flipped) + struct.pack("!I", crc32c(payload)))
        with pytest.raises(rm.FrameChecksumError):
            rm.recv_frame(b)
        # FrameChecksumError is a RemoteProtocolError (typed, and the
        # generic protocol-error handling applies), and retryable
        assert issubclass(rm.FrameChecksumError, rm.RemoteProtocolError)
    finally:
        a.close()
        b.close()


def test_oversized_and_garbage_frames_raise_typed_errors():
    a, b = _framed_pair()
    try:
        a.sendall(struct.pack("!I", rm.MAX_FRAME_BYTES + 1))
        with pytest.raises(rm.RemoteProtocolError):
            rm.recv_frame(b)
        junk = b"\x00\xffnot json"
        a.sendall(struct.pack("!I", len(junk) | rm.FRAME_CRC_FLAG) + junk
                  + struct.pack("!I", crc32c(junk)))
        with pytest.raises(rm.RemoteProtocolError):
            rm.recv_frame(b)
    finally:
        a.close()
        b.close()


def test_client_closes_connection_on_protocol_error():
    """Satellite: a garbage frame must not leave a desynced pooled
    connection behind — the client closes the socket so the pool can
    only ever hand out connections at a frame boundary."""
    a, b = _framed_pair()
    client = rm.WorkerClient(("127.0.0.1", 1))
    client._sock = b
    try:
        a.sendall(struct.pack("!I", rm.MAX_FRAME_BYTES + 1))
        with pytest.raises(rm.RemoteProtocolError):
            client.recv()
        assert not client.connected  # closed, never reusable desynced
    finally:
        a.close()
        client.close()


def test_faulty_transport_drop_and_truncate_surface_as_socket_errors():
    plan = FaultPlan(0)
    plan.force("send", "drop")
    a, b = _framed_pair()
    try:
        t = faults.FaultyTransport(a, plan)
        with pytest.raises(OSError):
            t.sendall(b"x" * 64)
        assert plan.injected[("send", "drop")] == 1
    finally:
        a.close()
        b.close()
    # truncate: the peer reads a strict prefix then EOF -> torn frame
    plan = FaultPlan(1)
    plan.force("send", "truncate")
    a, b = _framed_pair()
    try:
        with pytest.raises(OSError):
            faults.FaultyTransport(a, plan).sendall(b"y" * 64)
        got = bytearray()
        while True:
            chunk = b.recv(4096)
            if not chunk:
                break
            got += chunk
        assert 0 < len(got) < 64
    finally:
        a.close()
        b.close()


def test_faulty_transport_bitflip_is_caught_by_frame_checksum():
    plan = FaultPlan(2)
    plan.force("send", "bitflip")
    a, b = _framed_pair()
    try:
        rm.send_frame(faults.FaultyTransport(a, plan), {"op": "ping"})
        with pytest.raises(rm.FrameChecksumError):
            rm.recv_frame(b)
    finally:
        a.close()
        b.close()


# ===========================================================================
# RetryPolicy + CircuitBreaker (fake clocks)
# ===========================================================================

class _FakeClock:
    def __init__(self):
        self.t = 0.0
        self.sleeps = []

    def now(self):
        return self.t

    def sleep(self, s):
        self.sleeps.append(s)
        self.t += s


def test_backoff_grows_exponentially_and_caps():
    p = RetryPolicy(max_attempts=8, base_delay_s=0.02, max_delay_s=0.25,
                    multiplier=2.0)
    assert [p.backoff_s(k) for k in range(6)] == \
        [0.02, 0.04, 0.08, 0.16, 0.25, 0.25]


def test_retry_succeeds_after_transients_and_sleeps_backoffs():
    clk = _FakeClock()
    p = RetryPolicy(max_attempts=4, base_delay_s=0.02, max_delay_s=0.25,
                    sleep=clk.sleep, now=clk.now)
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise ConnectionError("transient")
        return "ok"

    assert p.run(flaky, retry_on=(ConnectionError,)) == "ok"
    assert clk.sleeps == [0.02, 0.04]


def test_retry_exhausts_attempts_with_last_error():
    clk = _FakeClock()
    p = RetryPolicy(max_attempts=3, sleep=clk.sleep, now=clk.now)
    with pytest.raises(ConnectionError):
        p.run(lambda: (_ for _ in ()).throw(ConnectionError("x")),
              retry_on=(ConnectionError,))
    assert len(clk.sleeps) == 2  # 3 attempts, 2 backoffs


def test_non_retryable_exception_escapes_immediately():
    clk = _FakeClock()
    p = RetryPolicy(max_attempts=5, sleep=clk.sleep, now=clk.now)
    with pytest.raises(KeyError):
        p.run(lambda: (_ for _ in ()).throw(KeyError("x")),
              retry_on=(ConnectionError,))
    assert clk.sleeps == []


def test_deadline_budget_raises_instead_of_overstaying():
    """The budget check happens *before* the sleep: when the next
    backoff would cross the deadline, RetryBudgetExceeded fires and no
    time is burned just to fail again."""
    clk = _FakeClock()
    p = RetryPolicy(max_attempts=10, base_delay_s=0.1, max_delay_s=10.0,
                    multiplier=2.0, sleep=clk.sleep, now=clk.now)
    with pytest.raises(RetryBudgetExceeded):
        p.run(lambda: (_ for _ in ()).throw(ConnectionError("x")),
              retry_on=(ConnectionError,), deadline_s=0.35)
    # slept 0.1 + 0.2 = 0.3; the next 0.4 backoff would cross 0.35
    assert clk.sleeps == [0.1, 0.2]
    assert clk.t <= 0.35
    assert issubclass(RetryBudgetExceeded, TimeoutError)


def test_breaker_trips_after_consecutive_failures_only():
    clk = _FakeClock()
    br = CircuitBreaker(failure_threshold=3, reset_timeout_s=5.0,
                        now=clk.now)
    for _ in range(2):
        br.record_failure()
    br.record_success()  # success resets the consecutive count
    for _ in range(2):
        br.record_failure()
    assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "open" and not br.allow()
    assert br.snapshot()["opens"] == 1
    assert br.snapshot()["rejections"] == 1


def test_breaker_half_open_probe_is_single_flight():
    clk = _FakeClock()
    br = CircuitBreaker(failure_threshold=1, reset_timeout_s=5.0,
                        now=clk.now)
    br.record_failure()
    assert not br.allow()
    clk.t = 6.0  # past the reset timeout
    assert br.allow()           # the single-flight probe
    assert br.state == "half_open"
    assert not br.allow()       # concurrent callers rejected
    assert not br.allow()
    br.record_success()
    assert br.state == "closed" and br.allow()


def test_breaker_failed_probe_reopens_for_a_full_timeout():
    clk = _FakeClock()
    br = CircuitBreaker(failure_threshold=1, reset_timeout_s=5.0,
                        now=clk.now)
    br.record_failure()
    clk.t = 5.0
    assert br.allow()
    br.record_failure()  # probe failed
    assert br.state == "open"
    clk.t = 9.9  # fresh timeout from the probe failure, not the first
    assert not br.allow()
    clk.t = 10.0
    assert br.allow()


def test_breaker_aborted_probe_releases_the_slot():
    """A probe abandoned without an outcome (scatter aborted because a
    *different* shard failed) must not wedge the breaker: the slot is
    released and the circuit re-opens for another timed probe."""
    clk = _FakeClock()
    br = CircuitBreaker(failure_threshold=1, reset_timeout_s=5.0,
                        now=clk.now)
    br.record_failure()
    clk.t = 5.0
    assert br.allow()
    br.record_abort()
    assert br.state == "open"
    clk.t = 10.0
    assert br.allow()  # a fresh probe gets through — not wedged
    br.record_success()
    assert br.state == "closed"


# ===========================================================================
# WAL line checksums
# ===========================================================================

def test_wal_round_trip_and_torn_tail(tmp_path):
    wal = tmp_path / "wal.log"
    lines = [segmentio.wal_encode_line(f"payload {i}") for i in range(5)]
    wal.write_text("\n".join(lines) + "\n")
    assert segmentio.read_complete_wal_lines(wal) == \
        [f"payload {i}" for i in range(5)]
    # a torn final line (crash mid-append) is silently dropped
    wal.write_text("\n".join(lines) + "\n"
                   + segmentio.wal_encode_line("torn")[:-3])
    assert segmentio.read_complete_wal_lines(wal) == \
        [f"payload {i}" for i in range(5)]


def test_wal_mid_file_corruption_raises_typed_error(tmp_path):
    """Satellite: only the *final* line may fail its checksum.  A bad
    line with valid lines after it means acknowledged records were
    damaged at rest — replay must stop with WalCorruptionError, not
    silently drop data."""
    wal = tmp_path / "wal.log"
    lines = [segmentio.wal_encode_line(f"payload {i}") for i in range(5)]
    corrupt = lines[2][:9] + "X" + lines[2][10:]  # damage the payload
    wal.write_text("\n".join(lines[:2] + [corrupt] + lines[3:]) + "\n")
    with pytest.raises(segmentio.WalCorruptionError):
        segmentio.read_complete_wal_lines(wal)


def test_wal_legacy_bare_lines_stay_lenient(tmp_path):
    wal = tmp_path / "wal.log"
    wal.write_text("bare line 0\nbare line 1\nto rn")
    assert segmentio.read_complete_wal_lines(wal) == \
        ["bare line 0", "bare line 1"]


def test_store_wal_is_checksummed_and_survives_reload(tmp_path):
    st = ColumnarMetricStore(directory=tmp_path / "s", seal_threshold=29)
    for rec in RECORDS[:40]:
        st.insert(rec)
    want = query(st, FLEET_Q)
    raw = (tmp_path / "s" / "wal.log").read_text().splitlines()
    assert raw and all(len(ln) > 9 and ln[8] == " " for ln in raw)
    st.close()
    back = ColumnarMetricStore(directory=tmp_path / "s", seal_threshold=29)
    rows_identical(query(back, FLEET_Q), want, FLEET_Q)
    back.close()


# ===========================================================================
# Seal faults: ENOSPC + torn segment commits
# ===========================================================================

def test_enospc_seal_fails_typed_and_store_recovers(
        tmp_path, clean_storage_faults):
    st = ColumnarMetricStore(directory=tmp_path / "s", seal_threshold=10**6)
    for rec in RECORDS[:60]:
        st.insert(rec)
    want = query(st, FLEET_Q)
    plan = FaultPlan(0)
    plan.force("seal", "enospc")
    faults.install_storage_faults(plan)
    with pytest.raises(OSError) as ei:
        st.seal()
    assert ei.value.errno == 28  # ENOSPC
    # nothing was lost: the rows stayed in the buffer + WAL
    rows_identical(query(st, FLEET_Q), want, FLEET_Q)
    faults.install_storage_faults(None)
    st.seal()  # the disk "recovered": sealing now succeeds
    rows_identical(query(st, FLEET_Q), want, FLEET_Q)
    st.close()
    back = ColumnarMetricStore(directory=tmp_path / "s")
    rows_identical(query(back, FLEET_Q), want, FLEET_Q)
    back.close()


@pytest.mark.parametrize("kind", ["torn_bin", "torn_manifest"])
def test_torn_seal_is_invisible_and_wal_recovers(
        tmp_path, kind, clean_storage_faults):
    st = ColumnarMetricStore(directory=tmp_path / "s", seal_threshold=10**6)
    for rec in RECORDS[:60]:
        st.insert(rec)
    want = query(st, FLEET_Q)
    plan = FaultPlan(0)
    plan.force("seal", kind)
    faults.install_storage_faults(plan)
    with pytest.raises(OSError):
        st.seal()
    st.close()  # simulate the crash: reopen from disk only
    faults.install_storage_faults(None)
    back = ColumnarMetricStore(directory=tmp_path / "s", seal_threshold=29)
    rows_identical(query(back, FLEET_Q), want, FLEET_Q)
    back.close()


# ===========================================================================
# Quarantine: checksum mismatch degrades, never crashes
# ===========================================================================

def _flip_byte(path, offset=100):
    data = bytearray(path.read_bytes())
    data[min(offset, len(data) - 1)] ^= 0xFF
    path.write_bytes(bytes(data))


def test_corrupt_segment_quarantined_at_open(tmp_path):
    st = ColumnarMetricStore(directory=tmp_path / "s", seal_threshold=29)
    for rec in RECORDS[:120]:
        st.insert(rec)
    assert len(st._sealed) >= 2
    st.close()
    segs = sorted((tmp_path / "s" / "segments").glob("*.bin"))
    _flip_byte(segs[0])
    back = ColumnarMetricStore(directory=tmp_path / "s", seal_threshold=29)
    assert back.quarantined_segments == 1
    assert back.storage_stats()["quarantined_segments"] == 1
    qdir = tmp_path / "s" / "segments" / segmentio.QUARANTINE_DIRNAME
    assert (qdir / segs[0].name).exists()  # kept for forensics
    assert not segs[0].exists()
    # the store still serves every byte it can prove intact
    assert query(back, "stats count") != []
    back.close()
    # reopening again does not re-count the quarantined stem
    again = ColumnarMetricStore(directory=tmp_path / "s", seal_threshold=29)
    assert again.quarantined_segments == 0
    again.close()


def test_read_only_open_skips_corrupt_segment_without_moving_it(tmp_path):
    st = ColumnarMetricStore(directory=tmp_path / "s", seal_threshold=29)
    for rec in RECORDS[:120]:
        st.insert(rec)
    st.close()
    segs = sorted((tmp_path / "s" / "segments").glob("*.bin"))
    _flip_byte(segs[0])
    ro = ColumnarMetricStore(directory=tmp_path / "s", read_only=True)
    assert ro.quarantined_segments == 1
    assert segs[0].exists()  # read-only: counted, not moved
    ro.close()


def test_query_time_decode_error_quarantines_and_degrades(
        tmp_path, monkeypatch):
    st = ColumnarMetricStore(directory=tmp_path / "s", seal_threshold=29)
    for rec in RECORDS[:120]:
        st.insert(rec)
    victim = st._sealed[0]
    n_sealed = len(st._sealed)
    real = splunklite._segment_partials

    def boom(seg, plan):
        if seg is victim:
            raise ValueError("decode blew up (injected)")
        return real(seg, plan)

    monkeypatch.setattr(splunklite, "_segment_partials", boom)
    plan = splunklite.compile_scatter_plan(
        splunklite._split_pipeline("stats count by kind"))
    stats = {}
    splunklite.scatter_partials(st, plan, stats=stats)
    assert stats["quarantined_segments"] == 1
    assert st.quarantined_segments == 1
    assert len(st._sealed) == n_sealed - 1
    monkeypatch.setattr(splunklite, "_segment_partials", real)
    # the store keeps answering on what survived, and the files moved
    assert query(st, "stats count") != []
    qdir = tmp_path / "s" / "segments" / segmentio.QUARANTINE_DIRNAME
    assert len(list(qdir.glob("*.bin"))) == 1
    st.close()


# ===========================================================================
# Remote fleet: idempotent retries, breakers, kill/restart
# ===========================================================================

def test_retried_mutation_applies_at_most_once(tmp_path):
    """A reply dropped *after* the worker applied the mutation is the
    classic at-least-once hazard: the coordinator retries, the worker
    must recognize the idempotency key and replay the cached reply
    instead of inserting twice."""
    plan = FaultPlan(0)  # no rates: only the scripted drop below fires
    agg = RemoteShardedAggregator(num_shards=1, directory=tmp_path / "f",
                                  seal_threshold=SEAL,
                                  worker_idle_timeout_s=IDLE_S,
                                  spawn_timeout_s=60.0, fault_plan=plan)
    try:
        for rec in RECORDS[:50]:
            agg.insert(rec)
        n = len(agg)
        plan.force("recv", "drop")  # lose exactly one reply in transit
        assert agg.insert(MetricRecord(99999.0, "n0", "idem.1", "perf",
                                       {"gflops": 1.0}))
        assert len(agg) == n + 1  # applied exactly once
        r = agg.shards[0].rpc("explain", fingerprint="")
        assert r["idem_replays"] == 1
        assert agg.robustness_stats()["retries"] >= 1
    finally:
        agg.close()


def test_breaker_opens_on_dead_worker_and_probe_heals_after_restart(
        tmp_path):
    agg = RemoteShardedAggregator(num_shards=2, directory=tmp_path / "f",
                                  seal_threshold=SEAL,
                                  worker_idle_timeout_s=IDLE_S,
                                  spawn_timeout_s=60.0,
                                  breaker_threshold=2, breaker_reset_s=0.2,
                                  retry=None)
    try:
        for rec in RECORDS[:80]:
            agg.insert(rec)
        want = query(agg, FLEET_Q)
        agg.kill_worker(1)
        for _ in range(4):  # degraded reads; failures feed the breaker
            rows_identical(query(agg, FLEET_Q), want, FLEET_Q)
        rob = agg.robustness_stats()
        assert rob["opens"] >= 1
        assert agg.shards[1].breaker.state in ("open", "half_open")
        # fail-fast while open: CircuitOpen is a WorkerUnavailable, so
        # the degraded path absorbs it without a connect attempt
        assert issubclass(rm.CircuitOpen, rm.WorkerUnavailable)
        agg.restart_worker(1)  # connect() success closes the breaker
        assert agg.shards[1].breaker.state == "closed"
        rows_identical(query(agg, FLEET_Q), want, FLEET_Q)
        assert agg.last_query_stats["degraded_shards"] == 0
    finally:
        agg.close()


def test_worker_kill_mid_op_fails_over_on_replicated_fleet(tmp_path):
    oracle = random_store(records=RECORDS, shards=2, seal_threshold=SEAL)
    agg = make_fleet(tmp_path / "f")
    try:
        agg.sync_replicas()
        want = {q: query(oracle, q) for q in SWEEP}
        # arm member (0, primary): the *next* op it serves kills it
        agg.shards[0].members[0].rpc("set_faults", kill_after_ops=0)
        for q in SWEEP:
            rows_identical(query(agg, q), want[q], q)
        rep = agg.replication_stats()
        assert rep["failovers"] + rep["hedged_ops"] >= 1
        agg.restart_worker(0, member=0)
        agg.sync_replicas()
        for q in SWEEP:
            rows_identical(query(agg, q), want[q], q)
    finally:
        agg.close()
        oracle.close()


def test_worker_seal_faults_surface_as_typed_errors(tmp_path):
    agg = RemoteShardedAggregator(num_shards=1, directory=tmp_path / "f",
                                  seal_threshold=10**6,
                                  worker_idle_timeout_s=IDLE_S,
                                  spawn_timeout_s=60.0)
    try:
        for rec in RECORDS[:50]:
            agg.insert(rec)
        r = agg.shards[0].rpc("set_faults", seal_enospc=1)
        assert r["installed"]
        with pytest.raises(rm.WorkerError):
            agg.seal()
        agg.shards[0].rpc("set_faults", clear=True)
        agg.seal()  # recovered
        assert query(agg, "stats count") != []
    finally:
        agg.close()


def test_robustness_counters_visible_in_explain_and_stats(tmp_path):
    agg = make_fleet(tmp_path / "f", records=RECORDS[:100])
    try:
        agg.sync_replicas()
        ex = agg.explain(FLEET_Q)
        rob = ex["robustness"]
        assert rob["breakers"] == 4  # 2 shards x 2 replicas
        assert rob["frame_checksums"] and rob["retry_enabled"]
        for key in ("retries", "checksum_errors", "deadline_exceeded",
                    "open", "opens", "rejections", "crc_impl"):
            assert key in rob
        for w in ex["workers"]:
            assert "retries" in w and "checksum_errors" in w
            assert len(w["breakers"]) == 2
        from repro.core.service import QueryService
        svc = QueryService(agg)
        try:
            assert svc.stats()["robustness"]["breakers"] == 4
        finally:
            svc.close()
    finally:
        agg.close()


# ===========================================================================
# Chaos parity: randomized fault schedules over a replicated fleet
# ===========================================================================

CHAOS_SEEDS = [int(s) for s in
               os.environ.get("CHAOS_SEEDS", "101,202,303").split(",")]

#: modest per-call rates: with pooled connections a scatter makes many
#: transport calls, so even 2-3% per call faults most queries
CHAOS_RATES = {
    "send": {"drop": 0.01, "truncate": 0.01, "bitflip": 0.02,
             "delay": 0.05},
    "recv": {"drop": 0.01, "truncate": 0.01, "bitflip": 0.02,
             "delay": 0.05},
}


def _chaos_round(agg, oracle_rows, q, deadline_s):
    """One chaos query: byte-identical to the oracle, or a typed error,
    always inside the deadline.  Returns (ok, typed_error)."""
    t0 = time.monotonic()
    try:
        got = query(agg, q)
    except TYPED_ERRORS:
        elapsed = time.monotonic() - t0
        assert elapsed < deadline_s, \
            f"typed error after {elapsed:.1f}s exceeded deadline for {q!r}"
        return 0, 1
    elapsed = time.monotonic() - t0
    assert elapsed < deadline_s, \
        f"query took {elapsed:.1f}s, deadline {deadline_s}s: {q!r}"
    rows_identical(got, oracle_rows, q)
    return 1, 0


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_smoke_parity_under_wire_faults(tmp_path, seed):
    """CI smoke (three fixed seeds): randomized wire faults against a
    replicated 2x2 fleet — every query byte-identical or a typed error,
    within a hard wall-clock deadline (never a hang)."""
    oracle = random_store(records=RECORDS, shards=2, seal_threshold=SEAL)
    plan = FaultPlan(seed)  # rates activate after clean ingest
    agg = make_fleet(tmp_path / "f", fault_plan=plan, op_timeout_s=15.0)
    try:
        agg.sync_replicas()
        want = {q: query(oracle, q) for q in SWEEP}
        plan.rates = {site: dict(kinds)
                      for site, kinds in CHAOS_RATES.items()}
        ok = err = 0
        for _round in range(4):
            for q in SWEEP:
                a, b = _chaos_round(agg, want[q], q, deadline_s=60.0)
                ok += a
                err += b
        plan.rates = {}
        assert ok >= len(SWEEP)  # retries must absorb most faults
        rob = agg.robustness_stats()
        assert plan.injected_total() > 0
        # parity holds again once the network heals
        for q in SWEEP:
            rows_identical(query(agg, q), want[q], q)
        assert isinstance(rob["retries"], int)
    finally:
        agg.close()
        oracle.close()


@pytest.mark.slow
def test_chaos_property_parity_over_randomized_schedules(tmp_path):
    """Acceptance property: 200+ randomized fault schedules (wire fault
    mixes + worker kills) over a replicated 4-worker fleet.  Every
    query returns rows byte-identical to the fault-free oracle or
    raises a typed error within its deadline — never a hang, never a
    silently wrong answer."""
    import random as _random
    master = _random.Random(20260809)
    oracle = random_store(records=RECORDS, shards=2, seal_threshold=SEAL)
    plan = FaultPlan(0)
    agg = make_fleet(tmp_path / "f", fault_plan=plan, op_timeout_s=15.0)
    schedules = int(os.environ.get("CHAOS_SCHEDULES", "200"))
    try:
        agg.sync_replicas()
        want = {q: query(oracle, q) for q in SWEEP}
        ok = err = 0
        for round_no in range(schedules):
            rates = {}
            for site in ("send", "recv"):
                kinds = {}
                for kind in faults.WIRE_FAULTS:
                    if master.random() < 0.5:
                        kinds[kind] = master.uniform(0.0, 0.04)
                if kinds:
                    rates[site] = kinds
            plan.rates = rates
            q = SWEEP[master.randrange(len(SWEEP))]
            a, b = _chaos_round(agg, want[q], q, deadline_s=60.0)
            ok += a
            err += b
            if round_no % 40 == 39:  # periodic worker murder + heal
                plan.rates = {}
                i = master.randrange(len(agg.shards))
                member = master.randrange(2)
                agg.kill_worker(i, member=member)
                rows_identical(query(agg, SWEEP[0]), want[SWEEP[0]],
                               SWEEP[0])
                agg.restart_worker(i, member=member)
                agg.sync_replicas()
        plan.rates = {}
        assert ok + err == schedules
        assert ok > schedules // 2, (ok, err)  # hardening absorbs most
        for q in SWEEP:  # healed fleet: full parity again
            rows_identical(query(agg, q), want[q], q)
    finally:
        agg.close()
        oracle.close()
