"""Pallas kernel validation (interpret mode) against pure-jnp oracles:
shape/dtype sweeps + hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import flash_attention_op, ssd_op
from repro.kernels.ref import ref_attention, ref_ssd_intra_chunk
from repro.kernels.ssd_scan import ssd_intra_chunk
from repro.models.ssm import ssd_chunked


def _mk_qkv(key, b, sq, skv, hq, hkv, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), jnp.float32)
    return q.astype(dtype), k.astype(dtype), v.astype(dtype)


SWEEP = [
    # (b, sq, skv, hq, hkv, d, window, cap, dtype)
    (1, 128, 128, 4, 4, 32, 0, 0.0, jnp.float32),
    (2, 128, 128, 4, 2, 64, 0, 50.0, jnp.float32),
    (1, 256, 256, 8, 2, 32, 64, 0.0, jnp.float32),
    (2, 64, 256, 4, 1, 16, 0, 0.0, jnp.float32),   # q shorter (decode-ish)
    (1, 1, 128, 4, 2, 64, 0, 0.0, jnp.float32),    # single-token decode
    (1, 96, 96, 2, 2, 32, 17, 30.0, jnp.float32),  # odd sizes + both caps
    (1, 128, 128, 4, 4, 32, 0, 0.0, jnp.bfloat16),
    (2, 128, 128, 8, 4, 128, 32, 50.0, jnp.bfloat16),
]


@pytest.mark.parametrize("b,sq,skv,hq,hkv,d,window,cap,dtype", SWEEP)
def test_flash_attention_sweep(b, sq, skv, hq, hkv, d, window, cap, dtype):
    q, k, v = _mk_qkv(jax.random.PRNGKey(0), b, sq, skv, hq, hkv, d, dtype)
    off = skv - sq
    out = flash_attention_op(q, k, v, causal=True, window=window,
                             softcap=cap, q_offset=off, block_q=64,
                             block_k=64, interpret=True)
    ref = ref_attention(q, k, v, causal=True, window=window, softcap=cap,
                        q_offset=off)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_flash_attention_kv_len_masking():
    q, k, v = _mk_qkv(jax.random.PRNGKey(1), 1, 8, 128, 2, 2, 32,
                      jnp.float32)
    out = flash_attention_op(q, k, v, causal=False, kv_len=100,
                             block_q=64, block_k=64, interpret=True)
    ref = ref_attention(q, k, v, causal=False, kv_len=100)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@given(sq=st.sampled_from([32, 64, 100]),
       hkv=st.sampled_from([1, 2]),
       g=st.sampled_from([1, 2, 3]),
       d=st.sampled_from([16, 32]),
       window=st.sampled_from([0, 8, 24]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_flash_attention_property(sq, hkv, g, d, window, seed):
    q, k, v = _mk_qkv(jax.random.PRNGKey(seed), 1, sq, sq, hkv * g, hkv,
                      d, jnp.float32)
    out = flash_attention_op(q, k, v, causal=True, window=window,
                             block_q=32, block_k=32, interpret=True)
    ref = ref_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


SSD_SWEEP = [
    # (b, s, h, p, n, q)
    (1, 64, 2, 8, 16, 16),
    (2, 128, 4, 16, 32, 32),
    (1, 256, 3, 32, 64, 64),
]


@pytest.mark.parametrize("b,s,h,p,n,q", SSD_SWEEP)
def test_ssd_intra_chunk_sweep(b, s, h, p, n, q):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    xdt = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    a = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.1
    a_cs = jnp.cumsum(a.reshape(b, s // q, q, h), axis=2).reshape(b, s, h)
    bm = jax.random.normal(ks[2], (b, s, n)) * 0.3
    cm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    y, states = ssd_intra_chunk(xdt, a_cs, bm, cm, q, interpret=True)
    y_ref, st_ref = ref_ssd_intra_chunk(xdt, a_cs, bm, cm, q)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4)
    # kernel emits [B,C,H,N,P]; oracle [B,C,H,N,P] too
    np.testing.assert_allclose(np.asarray(states), np.asarray(st_ref),
                               atol=1e-4)


def test_ssd_op_matches_model_reference():
    key = jax.random.PRNGKey(3)
    B, S, H, P, N, Q = 2, 96, 4, 16, 32, 32
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, H))
    bm = jax.random.normal(ks[2], (B, S, N)) * 0.3
    cm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    h0 = jax.random.normal(ks[4], (B, H, P, N)) * 0.1
    y1, h1 = ssd_op(x, dt, a_log, bm, cm, chunk=Q, h0=h0, interpret=True)
    y2, h2 = ssd_chunked(x, dt, a_log, bm, cm, Q, h0=h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


@given(s=st.sampled_from([32, 64]), h=st.sampled_from([1, 3]),
       p=st.sampled_from([8, 16]), n=st.sampled_from([8, 32]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_ssd_op_property(s, h, p, n, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (1, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, s, h)))
    a_log = jnp.zeros((h,))
    bm = jax.random.normal(ks[2], (1, s, n)) * 0.3
    cm = jax.random.normal(ks[3], (1, s, n)) * 0.3
    y1, h1 = ssd_op(x, dt, a_log, bm, cm, chunk=16, interpret=True)
    y2, h2 = ssd_chunked(x, dt, a_log, bm, cm, 16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)
