"""Query engine tests: every command vs a numpy oracle + properties."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.aggregator import MetricStore
from repro.core.schema import MetricRecord
from repro.core.splunklite import QueryError, query


def make_store():
    store = MetricStore()
    rng = np.random.default_rng(0)
    for i in range(60):
        host = f"node{i % 3}"
        store.insert(MetricRecord(
            ts=1000.0 + i, host=host, job="jobA" if i % 2 == 0 else "jobB",
            kind="perf",
            fields={"gflops": float(rng.uniform(0, 100)),
                    "step": i, "app": "gemma" if i % 2 else "qwen"}))
    return store


def test_search_filters():
    store = make_store()
    rows = query(store, "search kind=perf job=jobA")
    assert rows and all(r["job"] == "jobA" for r in rows)
    rows = query(store, "search gflops>50")
    assert all(r["gflops"] > 50 for r in rows)
    rows = query(store, "search job=job* step>=10 step<20")
    assert all(10 <= r["step"] < 20 for r in rows)


def test_search_wildcard_and_negation():
    store = make_store()
    rows = query(store, "search app=gem*")
    assert rows and all(r["app"] == "gemma" for r in rows)
    rows = query(store, "search app!=gemma")
    assert rows and all(r["app"] != "gemma" for r in rows)


def test_stats_against_numpy():
    store = make_store()
    rows = query(store, "search kind=perf | stats avg(gflops) p50(gflops) "
                        "max(gflops) count by host")
    assert len(rows) == 3
    by_host = {}
    for rec in store.records:
        by_host.setdefault(rec.host, []).append(rec.fields["gflops"])
    for r in rows:
        xs = by_host[r["host"]]
        assert r["count"] == len(xs)
        assert r["avg_gflops"] == pytest.approx(np.mean(xs))
        assert r["max_gflops"] == pytest.approx(np.max(xs))
        assert r["p50_gflops"] == pytest.approx(
            np.quantile(xs, 0.5, method="linear"), rel=1e-9)


def test_stats_alias_and_dc():
    store = make_store()
    rows = query(store, "search kind=perf | stats avg(gflops) as g dc(host)")
    assert "g" in rows[0] and rows[0]["dc_host"] == 3


def test_sort_head_fields_dedup():
    store = make_store()
    rows = query(store, "search kind=perf | sort -gflops | head 5")
    vals = [r["gflops"] for r in rows]
    assert vals == sorted(vals, reverse=True) and len(rows) == 5
    rows = query(store, "search kind=perf | fields host gflops | head 3")
    assert set(rows[0]) == {"host", "gflops"}
    rows = query(store, "search kind=perf | dedup host")
    assert len(rows) == 3


def test_timechart():
    store = make_store()
    rows = query(store, "search kind=perf | timechart span=10 avg(gflops)")
    assert rows and all("_time" in r for r in rows)
    assert rows == sorted(rows, key=lambda r: r["_time"])


def test_eval():
    store = make_store()
    rows = query(store, "search kind=perf "
                        "| eval tflops=gflops/1000 | head 2")
    for r in rows:
        assert r["tflops"] == pytest.approx(r["gflops"] / 1000)


def test_eval_rejects_dangerous():
    store = make_store()
    with pytest.raises(QueryError):
        query(store, "search kind=perf | eval "
                     "x=__import__('os').system('true')")


def test_unknown_command():
    with pytest.raises(QueryError):
        query(make_store(), "search | frobnicate")


@given(st.lists(st.floats(min_value=-1e6, max_value=1e6), min_size=1,
                max_size=50))
@settings(max_examples=60, deadline=None)
def test_stats_avg_property(xs):
    rows = [{"ts": float(i), "host": "h", "job": "j", "kind": "perf",
             "v": x} for i, x in enumerate(xs)]
    out = query(rows, "stats avg(v) sum(v) min(v) max(v) count")
    assert out[0]["count"] == len(xs)
    assert out[0]["avg_v"] == pytest.approx(np.mean(xs), rel=1e-9,
                                            abs=1e-9)
    assert out[0]["min_v"] == min(xs) and out[0]["max_v"] == max(xs)
