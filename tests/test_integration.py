"""End-to-end integration: monitored training producing queryable metrics,
reports, detector events; serving engine; elastic restart; dry-run cell.
"""

import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = Path(__file__).resolve().parents[1] / "src"


def test_monitored_training_end_to_end(tmp_path):
    from repro.configs import get_arch, reduced
    from repro.core import Aggregator, JobManifest, TrainMonitor, query
    from repro.core.report import generate_report
    from repro.core.transport import Shipper, StreamFileSink
    from repro.models import Model, ModelOptions
    from repro.data import Pipeline, SyntheticSource
    from repro.optim import AdamW, OptimizerConfig
    from repro.train import StepConfig, make_train_step

    cfg = reduced(get_arch("gemma3-4b"))
    model = Model(cfg, options=ModelOptions(remat_policy="full",
                                            attn_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(OptimizerConfig(warmup_steps=2, total_steps=30))
    state = opt.init(params)
    man = JobManifest(job_id="it.1", app=cfg.name, num_hosts=1,
                      num_chips=1)
    mon = TrainMonitor(tmp_path, man, host="h0", interval_s=0.0,
                       align_to_clock=False)
    src = SyntheticSource(cfg, 32, 4)
    pipe = Pipeline(src, stats=mon.pipeline_stats)
    step = make_train_step(model, opt, StepConfig(ce_seq_chunk=16))
    compiled = jax.jit(step).lower(params, state, None, {
        k: jnp.asarray(v) for k, v in src.get(0).items()}).compile()
    figures = mon.register_compiled(compiled, tokens_per_step=4 * 32)
    assert figures["flops"] > 0 and figures["dominant"] in (
        "compute", "memory", "collective")
    for i in range(6):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        params, state, _, metrics = compiled(params, state, None, batch)
        mon.on_step(i + 1, loss=float(metrics["loss"]), tokens=4 * 32)
    pipe.close()
    mon.stop()
    # ship -> aggregate -> query -> report
    agg = Aggregator(tmp_path / "inbox")
    Shipper(mon.daemon.spool.root,
            StreamFileSink(tmp_path / "inbox" / "h0.log")).ship_once()
    n = agg.pump()
    assert n > 0
    rows = query(agg.store, "search kind=perf gflops>0 "
                            "| stats avg(gflops) avg(mfu) count")
    assert rows and rows[0]["count"] >= 1
    rows = query(agg.store, "search kind=pipeline "
                            "| stats max(tokens_total)")
    assert rows[0]["max_tokens_total"] >= 6 * 128
    report = generate_report(agg.store, "it.1", tmp_path / "rep",
                             {"it.1": man})
    assert report.exists()
    html = (tmp_path / "rep" / "report.html").read_text()
    assert "svg" in html


def test_serve_engine_greedy(tmp_path):
    from repro.configs import get_arch, reduced
    from repro.models import Model, ModelOptions
    from repro.train.serve import ServeEngine, ServeRequest

    cfg = reduced(get_arch("qwen3-8b"))
    model = Model(cfg, options=ModelOptions(remat_policy="none",
                                            attn_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=2, max_len=64)
    eng.submit(ServeRequest(prompt=np.arange(5, dtype=np.int32) + 3,
                            max_new_tokens=4))
    eng.submit(ServeRequest(prompt=np.arange(8, dtype=np.int32) + 1,
                            max_new_tokens=4))
    done = eng.run()
    assert len(done) == 2
    for r in done:
        assert r.out.shape == (4,)
        assert (r.out >= 0).all() and (r.out < cfg.vocab_size).all()


@pytest.mark.slow
def test_elastic_restart_after_injected_failure(tmp_path):
    """Supervisor restarts a deliberately-crashing child; training
    completes from checkpoint."""
    cmd = [sys.executable, "-m", "repro.launch.elastic",
           "--workdir", str(tmp_path), "--max-restarts", "2", "--",
           "--arch", "qwen3-8b", "--reduced", "--steps", "12",
           "--seq-len", "32", "--batch", "4", "--checkpoint-every", "4",
           "--monitor-interval", "0.5", "--fail-at-step", "6",
           "--job-id", "elastic.test"]
    env = {"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
           "HOME": "/root"}
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=420,
                         env=env)
    assert "injected failure" in out.stdout
    assert "resumed from step" in out.stdout
    assert "[elastic] job completed" in out.stdout, out.stdout[-2000:]


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """One real dry-run cell (decode — fastest compile) on the 512-device
    production mesh, exercising the exact deliverable-(e) path."""
    out_dir = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
           "mamba2-780m", "--shape", "decode_32k"]
    env = {"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin",
           "HOME": "/root"}
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=560,
                         env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.loads(
        (out_dir / "16x16" / "mamba2-780m__decode_32k.json").read_text())
    assert rec["ok"] and rec["chips"] == 256
    assert rec["fits_hbm"]
    assert rec["dominant"] in ("compute", "memory", "collective")
