"""CI guard: fail when a tracked benchmark row regresses vs a baseline.

Usage::

    python benchmarks/check_regression.py BASELINE.json CURRENT.json \
        [--row splunklite.fleet_query] [--factor 1.5]

Compares ``us_per_call`` of the named row between the committed baseline
(e.g. ``git show HEAD:experiments/BENCH_splunklite.json``) and a fresh
run; exits non-zero when current > factor * baseline.  A row missing
from the baseline passes (first run of a new benchmark); a row missing
from the current results fails (the benchmark stopped producing it).

``--normalize-row`` divides both sides by another row measured in the
same run (e.g. the legacy row-engine time for the same query), so the
comparison is a machine-independent ratio — a CI runner slower than
the machine that produced the committed baseline does not trip the
guard, and a genuinely regressed code path still does.

``--max-ratio`` guards an *absolute* bound instead of a trajectory:
the current row (normalized by ``--normalize-row`` from the same run)
must stay <= the bound regardless of what the baseline recorded — used
for invariants like "fault-tolerance overhead <= 1.15x the unhardened
path".  With ``--max-ratio`` the baseline file is still required on
the command line but never consulted.
"""

from __future__ import annotations

import argparse
import json
import sys


def row_us(doc: dict, name: str):
    for r in doc.get("rows", []):
        if r.get("name") == name:
            return r.get("us_per_call")
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--row", default="splunklite.fleet_query")
    ap.add_argument("--factor", type=float, default=1.5)
    ap.add_argument("--normalize-row", default=None)
    ap.add_argument("--max-ratio", type=float, default=None)
    args = ap.parse_args(argv)
    with open(args.baseline, encoding="utf-8") as f:
        base_doc = json.load(f)
    with open(args.current, encoding="utf-8") as f:
        cur_doc = json.load(f)
    if args.max_ratio is not None:
        cur = row_us(cur_doc, args.row)
        if cur is None:
            print(f"[bench-guard] {args.row!r} missing from current "
                  "results")
            return 1
        if args.normalize_row is not None:
            cur_n = row_us(cur_doc, args.normalize_row)
            if not cur_n:
                print(f"[bench-guard] normalize row "
                      f"{args.normalize_row!r} missing from current "
                      "results")
                return 1
            cur = cur / cur_n
        ok = cur <= args.max_ratio
        print(f"[bench-guard] {args.row}: {cur:.3f}x "
              f"(bound {args.max_ratio:.2f}x) "
              f"{'OK' if ok else 'OVER BOUND'}")
        return 0 if ok else 1
    base = row_us(base_doc, args.row)
    cur = row_us(cur_doc, args.row)
    if base is None:
        print(f"[bench-guard] no baseline for {args.row!r}; skipping")
        return 0
    if cur is None:
        print(f"[bench-guard] {args.row!r} missing from current results")
        return 1
    unit = "us"
    if args.normalize_row is not None:
        base_n = row_us(base_doc, args.normalize_row)
        cur_n = row_us(cur_doc, args.normalize_row)
        if base_n and cur_n:
            base, cur, unit = base / base_n, cur / cur_n, "x-of-norm"
        else:
            print(f"[bench-guard] normalize row {args.normalize_row!r} "
                  "unavailable; comparing absolute times")
    ratio = cur / base
    ok = ratio <= args.factor
    print(f"[bench-guard] {args.row}: {base:.4g}{unit} -> {cur:.4g}{unit} "
          f"({ratio:.2f}x, limit {args.factor:.2f}x) "
          f"{'OK' if ok else 'REGRESSION'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
