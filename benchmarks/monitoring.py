"""Benchmarks for the monitoring system itself — one per paper
table/figure/claim.

* ``bench_data_volume``   — paper §5: ~3 KiB/node/sample, ~1.8 GiB/day for
  ~4200 nodes.  We measure OUR bytes/node/sample and extrapolate.
* ``bench_overhead``      — paper §4: "negligible overhead".  Train steps
  with monitoring on vs off.
* ``bench_roofline_view`` — paper Fig. 2: roofline overview render from a
  fleet of jobs.
* ``bench_job_view``      — paper Fig. 3: detailed job view (temporal +
  min/median/max statistical aggregation).
* ``bench_detectors``     — paper §4.4/§5 specialized views: planted
  anomalies; precision/recall + scan latency.
* ``bench_splunklite``    — query latency on a 100k-record store.
* ``bench_incremental``   — repeated fleet queries through the
  segment-keyed partial-aggregate cache: cold vs warm vs
  append-then-requery (docs/incremental.md).
* ``bench_compaction``    — docs/storage.md tiers: cold query pre/post
  segment compaction, compressed-tier byte ratio, rollup query vs the
  raw columnar scan it must match.
* ``bench_restart``       — §4.3 retention: aggregator cold-start from
  persisted columnar segments (mmap) vs full wire-line replay.
* ``bench_remote``        — remote shard execution (docs/remote.md):
  fleet query over 4 worker processes (overlapped scatter + worker-side
  partial caches) vs the same-run in-process sharded path.
* ``bench_service``       — multi-tenant query service (docs/service.md):
  p50/p99 latency and dedup hit rate under 8 simultaneous queriers
  (cheap dashboard refreshes + expensive batch scans) vs the same
  workload behind one global lock.
"""

from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from benchmarks.common import row, timeit


def _fleet_store(n_jobs=24, hosts_per_job=4, samples=30, seed=0,
                 plant_anomalies=True, store=None):
    """Synthetic fleet: healthy jobs + planted hang/idle/low-mfu jobs.
    Pass a pre-configured ``store`` (e.g. a durable one) to fill it."""
    from repro.core.aggregator import MetricStore
    from repro.core.daemon import JobManifest
    from repro.core.schema import MetricRecord
    rng = np.random.default_rng(seed)
    if store is None:
        store = MetricStore()
    manifests = {}
    planted = {"hang": set(), "idle_accelerator": set(), "low_mfu": set()}
    apps = ["gemma2-27b", "qwen3-8b", "mamba2-780m", "llama4-scout-17b-a16e"]
    for j in range(n_jobs):
        job = f"job.{j:03d}"
        app = apps[j % len(apps)]
        man = JobManifest(job_id=job, app=app, user=f"user{j % 5}",
                          num_hosts=hosts_per_job,
                          num_chips=hosts_per_job * 4)
        manifests[job] = man
        kind = "healthy"
        if plant_anomalies:
            if j % 8 == 5:
                kind = "hang"
                planted["hang"].add(job)
            elif j % 8 == 6:
                kind = "idle"
                planted["idle_accelerator"].add(job)
            elif j % 8 == 7:
                kind = "lowmfu"
                planted["low_mfu"].add(job)
        base_g = rng.uniform(40, 90)
        for h in range(hosts_per_job):
            host = f"node{j:03d}-{h}"
            for s in range(samples):
                ts = 1000.0 + s * 10.0
                stalled = kind == "hang" and s > samples // 2
                # idle-accelerator jobs still make (host-side) progress —
                # low but nonzero device numbers, hbm untouched
                g = (0.0 if stalled
                     else 5.0 if kind == "idle" else base_g * 16)
                mfu = (0.02 if kind == "lowmfu"
                       else (0.0 if g == 0 else rng.uniform(0.3, 0.5)))
                store.insert(MetricRecord(ts, host, job, "perf", {
                    "gflops": g, "gflops_per_chip": g / 16,
                    "mfu": mfu, "ai": float(rng.uniform(1, 300)),
                    "steps_per_s": 0.0 if stalled else 1.0,
                    "step_time_s": float(rng.uniform(0.9, 1.2)),
                    "step": s}))
                store.insert(MetricRecord(ts, host, job, "device", {
                    "hbm_frac_used": 0.01 if kind == "idle"
                    else float(rng.uniform(0.4, 0.8)),
                    "local_devices": 4}))
    return store, manifests, planted


def bench_data_volume(out_dir: Path):
    """Measure bytes per node per sample; extrapolate fleet volume."""
    import tempfile
    from repro.core.daemon import DaemonConfig, Hpcmd, JobManifest
    from repro.core.sources import (DeviceSource, EnvSource, ProcSource,
                                    StaticStepCost, StepClock,
                                    XlaCostSource)
    tmp = Path(tempfile.mkdtemp())
    clock = StepClock()
    d = Hpcmd(tmp / "spool", DaemonConfig(align_to_clock=False),
              host="bench-node", manifest=JobManifest(job_id="bench.1",
                                                      app="gemma2-27b"))
    src = XlaCostSource(clock)
    src.set_cost(StaticStepCost(flops=1e12, bytes=1e11,
                                collective_bytes=1e9, num_chips=4,
                                tokens_per_step=4096))
    d.add_source(src)
    d.add_source(DeviceSource())
    d.add_source(ProcSource())
    d.add_source(EnvSource())
    n_samples = 20
    for i in range(n_samples):
        clock.record(i, tokens=4096, loss=2.0, ts=1000.0 + i)
        d.tick(1000.0 + i + 0.5)
    total = sum(p.stat().st_size for p in (tmp / "spool").glob("*.log"))
    bytes_per_sample = total / n_samples
    # paper: 10-min sampling, DRACO+COBRA ~= 4190 nodes
    nodes = 4190
    per_day = bytes_per_sample * nodes * (24 * 6)
    us = timeit(lambda: d.tick(time.time()), warmup=1, iters=10)
    return [
        row("data_volume.bytes_per_node_sample", us,
            f"{bytes_per_sample:.0f}B (paper ~3KiB)"),
        row("data_volume.fleet_per_day_gib", us,
            f"{per_day / 2**30:.2f}GiB@{nodes}nodes (paper ~1.8GiB)"),
    ]


def bench_overhead(out_dir: Path):
    """Per-step cost of monitoring: train with monitor on vs off."""
    import jax
    import jax.numpy as jnp
    import tempfile
    from repro.configs import get_arch, reduced
    from repro.core import JobManifest, TrainMonitor
    from repro.models import Model, ModelOptions
    from repro.data import SyntheticSource
    from repro.optim import AdamW, OptimizerConfig
    from repro.train import StepConfig, make_train_step

    cfg = reduced(get_arch("qwen3-8b"))
    model = Model(cfg, options=ModelOptions(remat_policy="full",
                                            attn_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(OptimizerConfig())
    state = opt.init(params)
    src = SyntheticSource(cfg, 64, 8)
    batch = {k: jnp.asarray(v) for k, v in src.get(0).items()}
    step = jax.jit(make_train_step(model, opt, StepConfig(ce_seq_chunk=32)))
    p2, s2, _, _ = step(params, state, None, batch)  # compile

    def run(monitor):
        p, s = params, state
        t0 = time.perf_counter()
        for i in range(20):
            p, s, _, m = step(p, s, None, batch)
            if monitor is not None:
                monitor.on_step(i, loss=1.0, tokens=512)
        jax.block_until_ready(p)
        return (time.perf_counter() - t0) / 20 * 1e6

    bare_us = run(None)
    tmp = Path(tempfile.mkdtemp())
    mon = TrainMonitor(tmp, JobManifest(job_id="ovh.1", app=cfg.name),
                       interval_s=0.5, align_to_clock=False)
    mon_us = run(mon)
    mon.stop()
    ovh = max(mon_us - bare_us, 0.0)
    pct = ovh / bare_us * 100
    return [
        row("overhead.bare_step", bare_us, "us/step"),
        row("overhead.monitored_step", mon_us,
            f"+{pct:.2f}% (paper: negligible)"),
    ]


def bench_roofline_view(out_dir: Path):
    """Fig. 2: roofline overview of a fleet."""
    from repro.core.dashboards import render_roofline_svg, roofline_points
    store, manifests, _ = _fleet_store()
    points = roofline_points(store, manifests)
    svg = render_roofline_svg(points)
    out = out_dir / "dashboards"
    out.mkdir(parents=True, exist_ok=True)
    (out / "roofline.svg").write_text(svg)
    us = timeit(lambda: render_roofline_svg(
        roofline_points(store, manifests)))
    return [row("roofline_view.render", us,
                f"{len(points)}jobs->{out / 'roofline.svg'}")]


def bench_job_view(out_dir: Path):
    """Fig. 3: detailed job view + statistical aggregation."""
    from repro.core.dashboards import (job_metric_series,
                                       job_statistical_view,
                                       render_timeseries_svg)
    store, manifests, _ = _fleet_store()
    job = "job.000"

    def render():
        series = job_metric_series(store, job, "gflops")
        stat = job_statistical_view(store, job, "gflops")
        s1 = render_timeseries_svg(series, "gflops", "gflops")
        s2 = render_timeseries_svg(stat, "stat", "gflops")
        return s1, s2

    s1, s2 = render()
    out = out_dir / "dashboards"
    out.mkdir(parents=True, exist_ok=True)
    (out / "job_view.svg").write_text(s1)
    (out / "job_view_stat.svg").write_text(s2)
    us = timeit(render)
    n = len(list(store.select(job=job, kind="perf")))
    return [row("job_view.render", us, f"{n}samples")]


def bench_detectors(out_dir: Path):
    """§4.4/§5 specialized views: planted-anomaly precision/recall."""
    from repro.core.detectors import DetectorBank
    store, manifests, planted = _fleet_store()
    bank = DetectorBank()
    events = bank.scan(store, manifests)
    results = []
    for det in ("hang", "idle_accelerator", "low_mfu"):
        found = {e.job for e in events if e.detector == det}
        want = planted[det]
        tp = len(found & want)
        prec = tp / len(found) if found else 1.0
        rec = tp / len(want) if want else 1.0
        results.append((det, prec, rec))
    us = timeit(lambda: DetectorBank().scan(store, manifests))
    rows = [row(f"detectors.{d}", us, f"prec={p:.2f},recall={r:.2f}")
            for d, p, r in results]
    assert all(p == 1.0 and r == 1.0 for _, p, r in results), results
    return rows


def bench_splunklite(out_dir: Path):
    """Query engine latency on a larger store: columnar executor vs the
    legacy row executor on the same query/workload, plus a 100k+-record
    columnar-only sample."""
    from repro.core.splunklite import query
    store, manifests, _ = _fleet_store(n_jobs=60, hosts_per_job=8,
                                       samples=40)
    q = ("search kind=perf gflops>0 "
         "| stats avg(gflops) p90(step_time_s) count by job "
         "| sort -avg_gflops | head 10")
    us = timeit(lambda: query(store, q), warmup=1, iters=5)
    us_rows = timeit(lambda: query(store, q, engine="rows"),
                     warmup=1, iters=3)
    rows = [
        row("splunklite.fleet_query", us, f"{len(store)}records"),
        row("splunklite.fleet_query_rows", us_rows,
            f"{len(store)}records,legacy={us_rows / max(us, 1e-9):.1f}x"),
    ]
    big, _m, _p = _fleet_store(n_jobs=110, hosts_per_job=8, samples=60)
    us_big = timeit(lambda: query(big, q), warmup=1, iters=5)
    rows.append(row("splunklite.fleet_query_100k", us_big,
                    f"{len(big)}records"))
    return rows


def bench_anomaly(out_dir: Path):
    """§4.6 outlook: streaming EWMA/CUSUM anomaly detection — planted
    regression recall + per-record latency."""
    import time as _t
    import numpy as np
    from repro.core.anomaly import AnomalyBank
    from repro.core.schema import MetricRecord
    rng = np.random.default_rng(0)
    recs = []
    for host in range(8):
        for s in range(200):
            g = 800 + rng.standard_normal() * 8
            if host == 3 and s >= 120:
                g = 350.0 + rng.standard_normal() * 8  # planted regression
            recs.append(MetricRecord(1000.0 + s, f"n{host}", "j1", "perf",
                                     {"gflops": float(g)}))
    # 6-sigma threshold: at 4 sigma a 1600-sample noise stream is
    # expected to produce ~1 false alarm (EWMA variance warmup); the
    # planted regression sits at ~55 sigma either way
    bank = AnomalyBank(metrics=("gflops",), z_thresh=6.0)
    t0 = _t.perf_counter()
    for r in recs:
        bank.feed(r)
    dt = (_t.perf_counter() - t0) / len(recs) * 1e6
    flagged_hosts = {e.fields.get("host") for e in bank.events
                     if e.detector == "ewma_anomaly"}
    hit = "n3" in flagged_hosts
    fp = len(flagged_hosts - {"n3"})
    assert hit and fp == 0, (flagged_hosts,)
    return [row("anomaly.ewma_stream", dt,
                f"recall=1.0,fp_hosts={fp},n={len(recs)}")]


def bench_sharded(out_dir: Path):
    """Sharded query fan-out vs the single-store path on the same
    ≥100k-record fleet workload and the same fleet query.  Emits the
    sharded time, the same-run single-store time (the CI guard
    normalizes by it so runner speed cancels), and an exact-gather
    fallback sample."""
    from repro.core.shards import ShardedAggregator
    from repro.core.splunklite import query
    single, _m, _p = _fleet_store(n_jobs=110, hosts_per_job=8, samples=60)
    sharded = ShardedAggregator(num_shards=4)
    _fleet_store(n_jobs=110, hosts_per_job=8, samples=60, store=sharded)
    assert len(sharded) == len(single)
    q = ("search kind=perf gflops>0 "
         "| stats avg(gflops) p90(step_time_s) count by job "
         "| sort -avg_gflops | head 10")
    # results agree (quantiles within the documented bound)
    got = {r["job"]: r for r in query(sharded, q)}
    want = {r["job"]: r for r in query(single, q)}
    assert got.keys() == want.keys()
    for job, w in want.items():
        assert got[job]["count"] == w["count"]
        assert abs(got[job]["avg_gflops"] - w["avg_gflops"]) <= 1e-6
    # interleave the two paths so allocator/CPU drift cancels out of
    # the ratio (they run on identical data in the same windows)
    sh_t, si_t = [], []
    query(sharded, q), query(single, q)  # warmup
    for _ in range(9):
        t0 = time.perf_counter()
        query(sharded, q)
        sh_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        query(single, q)
        si_t.append(time.perf_counter() - t0)
    us_sharded = sorted(sh_t)[len(sh_t) // 2] * 1e6
    us_single = sorted(si_t)[len(si_t) // 2] * 1e6
    assert sharded.scatter_queries > 0  # the plan actually fanned out
    ratio = us_sharded / max(us_single, 1e-9)
    # acceptance: fan-out must not lose to the single store it shards
    # (generous ceiling for noisy shared CI runners)
    assert ratio <= 1.35, (us_sharded, us_single)
    q_exact = "search kind=perf gflops>0 | stats first(app) by job"
    us_exact = timeit(lambda: query(sharded, q_exact), warmup=1, iters=3)
    return [
        row("sharded.fleet_query", us_sharded,
            f"{len(sharded)}records,4shards,{ratio:.2f}x_of_single"),
        row("sharded.fleet_query_single", us_single,
            f"{len(single)}records,same_run_baseline"),
        row("sharded.exact_gather", us_exact,
            f"{len(sharded)}records,row_gather_fallback"),
    ]


def bench_incremental(out_dir: Path):
    """Incremental query engine (docs/incremental.md): repeated fleet
    queries against the segment-keyed partial-aggregate cache on the
    ≥100k-record workload — cold (empty cache) vs warm (all sealed
    segments cached: only the append buffer recomputes) vs
    append-then-requery (buffer + newly sealed segments only), with
    byte parity between the cached and uncached runs asserted."""
    from repro.core.schema import MetricRecord
    from repro.core.shards import ShardedAggregator
    from repro.core.splunklite import query
    store, _m, _p = _fleet_store(n_jobs=110, hosts_per_job=8, samples=60)
    q = ("search kind=perf gflops>0 "
         "| stats avg(gflops) p90(step_time_s) count by job "
         "| sort -avg_gflops | head 10")

    def cold():
        store.partial_cache.clear()
        return query(store, q, engine="incremental")

    def warm():
        return query(store, q, engine="incremental")

    us_cold = timeit(cold, warmup=1, iters=5)
    warm()  # prime
    us_warm = timeit(warm, warmup=1, iters=9)
    # cached and uncached runs must be byte-identical
    store.partial_cache.clear()
    assert warm() == warm(), "warm rerun diverged"
    stats = store.last_query_stats
    assert stats["mode"] == "incremental"
    assert stats["segments_computed"] == 0, stats
    assert stats["segments_cached"] == len(store._sealed)
    speedup = us_cold / max(us_warm, 1e-9)
    # acceptance: a warm repeated fleet query is >= 5x cheaper than the
    # same-run cold scan (it only recomputes the append buffer)
    assert speedup >= 5.0, (us_cold, us_warm)
    # append-then-requery: new samples land in the buffer; the sealed
    # fleet stays cached (explain counters prove it)
    def append_requery():
        store.insert(MetricRecord(1e7 + append_requery.i, "nZ", "job.000",
                                  "perf", {"gflops": 1.0,
                                           "step": append_requery.i}))
        append_requery.i += 1
        return query(store, q, engine="incremental")
    append_requery.i = 0
    us_append = timeit(append_requery, warmup=1, iters=5)
    stats = store.last_query_stats
    assert stats["segments_computed"] == 0, stats
    assert stats["buffer_rows"] == len(store._buffer)
    # sharded stores consult per-shard caches on every query
    sharded = ShardedAggregator(num_shards=4)
    _fleet_store(n_jobs=110, hosts_per_job=8, samples=60, store=sharded)
    query(sharded, q)  # prime
    us_sh_warm = timeit(lambda: query(sharded, q), warmup=1, iters=9)
    assert sharded.last_query_stats["segments_computed"] == 0
    return [
        row("incremental.fleet_query_cold", us_cold,
            f"{len(store)}records,{len(store._sealed)}segments"),
        row("incremental.fleet_query_warm", us_warm,
            f"{speedup:.1f}x_vs_cold,buffer_only"),
        row("incremental.append_requery", us_append,
            f"buffer={len(store._buffer)}rows,0_segments_recomputed"),
        row("incremental.sharded_fleet_query_warm", us_sh_warm,
            "4shards,per-shard_caches"),
    ]


def bench_remote(out_dir: Path):
    """Remote shard execution (docs/remote.md): the ≥100k-record fleet
    workload is built into a durable 4-shard store, then served by 4
    worker processes.  Measures the warm remote fleet query (worker-
    side partial caches primed; only append buffers recompute) against
    the same-run in-process sharded warm latency, plus a cold run with
    worker caches cleared.  Asserts byte parity with the in-process
    result, the ≤3x warm-latency acceptance bound, and that the
    overlap path issued every shard request before the first merge.
    Workers are started and stopped under hard deadlines — a hung
    worker cannot wedge the job."""
    import shutil
    import tempfile
    from repro.core.remote import RemoteShardedAggregator
    from repro.core.shards import ShardedAggregator
    from repro.core.splunklite import query
    tmp = Path(tempfile.mkdtemp())
    fleet = None
    try:
        sharded = ShardedAggregator(num_shards=4, directory=tmp / "fleet",
                                    seal_threshold=4096)
        _fleet_store(n_jobs=110, hosts_per_job=8, samples=60, store=sharded)
        n = len(sharded)
        q = ("search kind=perf gflops>0 "
             "| stats avg(gflops) p90(step_time_s) count by job "
             "| sort -avg_gflops | head 10")
        query(sharded, q)  # prime the in-process per-shard caches
        us_inproc = timeit(lambda: query(sharded, q), warmup=1, iters=9)
        want = query(sharded, q)
        sharded.close()
        # the worker fleet re-adopts the durable shard dirs (segments
        # mmap back in, WAL tails replay) — the PR 2 restart path
        fleet = RemoteShardedAggregator(num_shards=4,
                                        directory=tmp / "fleet",
                                        seal_threshold=4096,
                                        worker_idle_timeout_s=300.0,
                                        spawn_timeout_s=60.0)
        assert len(fleet) == n

        def cold():
            for sh in fleet.shards:
                sh.rpc("clear_cache")
            fleet.drop_scatter_memos()
            return query(fleet, q)

        got = cold()
        assert got == want, "remote rows diverged from in-process sharded"
        us_cold = timeit(cold, warmup=1, iters=3)
        query(fleet, q)  # prime worker caches
        us_warm = timeit(lambda: query(fleet, q), warmup=1, iters=9)
        stats = fleet.last_query_stats
        assert stats["mode"] == "scatter_gather" and stats["remote"]
        assert stats["segments_computed"] == 0, stats
        assert stats["degraded_shards"] == 0, stats
        assert stats["overlap"], \
            "scatter must issue all shard requests before the first merge"
        ratio = us_warm / max(us_inproc, 1e-9)
        # acceptance: warm remote fleet query within 3x of the same-run
        # in-process sharded warm latency (wire framing + codec is the
        # only extra work — partials are small)
        assert ratio <= 3.0, (us_warm, us_inproc)
        return [
            row("remote.fleet_query_warm", us_warm,
                f"{n}records,4workers,{ratio:.2f}x_of_inproc"),
            row("remote.fleet_query_cold", us_cold,
                "worker_caches_cleared"),
            row("remote.fleet_query_inproc", us_inproc,
                "same_run_in_process_sharded_warm"),
        ]
    finally:
        if fleet is not None:
            fleet.close()
        shutil.rmtree(tmp, ignore_errors=True)


def bench_service(out_dir: Path):
    """Multi-tenant query service (docs/service.md) under load: 8
    simultaneous queriers — six dashboard tenants re-refreshing a small
    cheap query set (batch-deduped / result-cached) plus two analyst
    tenants running distinct expensive fleet scans at batch priority —
    against the ≥100k-record fleet store.  Measures per-op p50/p99
    latency under load, the dedup+cache hit rate, and aggregate
    throughput vs a *lock-serialized* direct path running the exact
    same thread/op mix (what the coordinator was before the service).
    Asserts byte parity with the direct path, ≥2x aggregate throughput
    vs the lock-serialized run, and that dedup actually collapsed the
    repeated refreshes.  The p99 row is normalized in CI by the
    same-run single-thread scan latency, keeping the guard
    machine-independent."""
    import threading
    from repro.core.service import QueryService
    from repro.core.splunklite import query

    store, _m, _p = _fleet_store(n_jobs=110, hosts_per_job=8, samples=60)
    n = len(store)
    cheap = [
        "search kind=perf | stats avg(gflops) count by job | sort job "
        "| head 15",
        "search kind=device | stats avg(hbm_frac_used) by job | sort job "
        "| head 15",
        "search kind=perf | timechart span=60 avg(mfu)",
    ]
    scans = [
        f"search kind=perf gflops>{x} | stats avg(gflops) p90(step_time_s) "
        "dc(host) by job | sort -avg_gflops | head 20"
        for x in (0, 100, 200, 300)
    ]
    want = {q: query(store, q) for q in cheap + scans}  # direct oracle

    def workload(run_op):
        """8 threads: 6 refreshers x 40 cheap ops, 2 scanners x 8 scans."""
        threads = [threading.Thread(
            target=lambda t=t: [run_op(t, cheap[i % len(cheap)], "cheap")
                                for i in range(40)]) for t in range(6)]
        threads += [threading.Thread(
            target=lambda t=t: [run_op(t, scans[i % len(scans)], "scan")
                                for i in range(8)]) for t in (6, 7)]
        t0 = time.perf_counter()
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        return (time.perf_counter() - t0) * 1e6

    # --- lock-serialized baseline: the pre-service coordinator shape
    big_lock = threading.Lock()
    locked_failures = []

    def locked_op(tenant, q, _klass):
        with big_lock:
            if query(store, q) != want[q]:  # pragma: no cover
                locked_failures.append(q)

    us_locked = workload(locked_op)
    assert not locked_failures

    # --- the service run: same mix, latencies recorded per op
    svc = QueryService(store, max_concurrency=4, tenant_quota=0)
    lat_lock = threading.Lock()
    latencies = []
    svc_failures = []

    def service_op(tenant, q, klass):
        t0 = time.perf_counter()
        rows = svc.query(q, tenant=f"t{tenant}",
                         priority="batch" if klass == "scan"
                         else "interactive")
        us = (time.perf_counter() - t0) * 1e6
        with lat_lock:
            latencies.append(us)
        if rows != want[q]:  # pragma: no cover
            svc_failures.append(q)

    us_svc = workload(service_op)
    counters = dict(svc.counters)
    svc.close()
    assert not svc_failures, "service rows diverged from direct path"
    latencies.sort()
    p50 = latencies[len(latencies) // 2]
    p99 = latencies[min(len(latencies) - 1, int(len(latencies) * 0.99))]
    hit_rate = ((counters["deduped"] + counters["result_cache_hits"])
                / max(counters["submitted"], 1))
    speedup = us_locked / max(us_svc, 1e-9)
    # acceptance: the repeated refreshes must coalesce (one execution
    # serves many waiters), and the same workload must clear 2x the
    # lock-serialized aggregate throughput
    assert counters["executed"] < counters["submitted"], counters
    assert hit_rate >= 0.3, counters
    assert speedup >= 2.0, (us_svc, us_locked)
    us_scan_serial = timeit(lambda: query(store, scans[0]),
                            warmup=1, iters=5)
    return [
        row("service.query_p50_loaded", p50,
            f"{n}records,8queriers"),
        row("service.query_p99_loaded", p99,
            f"dedup_hit_rate={hit_rate:.2f}"),
        row("service.scan_serial", us_scan_serial,
            "same_run_single_thread_direct"),
        row("service.workload_concurrent", us_svc,
            f"{speedup:.2f}x_vs_locked,executed={counters['executed']}"
            f"/{counters['submitted']}"),
        row("service.workload_locked", us_locked,
            "global_lock_direct_path"),
    ]


def bench_restart(out_dir: Path):
    """Aggregator cold-start on the 100k+-record fleet workload:
    mmap-load of persisted columnar segments (+ WAL replay of the
    unsealed tail) vs. full wire-line replay of a consolidated archive
    (the pre-persistence restart path)."""
    import shutil
    import tempfile
    from repro.core.aggregator import MetricStore
    from repro.core.schema import encode_line
    tmp = Path(tempfile.mkdtemp())
    try:
        store = MetricStore(seal_threshold=4096, directory=tmp / "store")
        _fleet_store(n_jobs=110, hosts_per_job=8, samples=60, store=store)
        n = len(store)
        wal_lines = len((tmp / "store" / "wal.log").read_text().splitlines())
        archive = [encode_line(r) for r in store.records]
        store.close()

        def cold_start():
            MetricStore(seal_threshold=4096, directory=tmp / "store").close()

        us_cold = timeit(cold_start, warmup=1, iters=3)
        us_replay = timeit(lambda: MetricStore(seal_threshold=4096)
                           .ingest_lines(archive), warmup=0, iters=1)
        speedup = us_replay / max(us_cold, 1e-9)
        # measured ~16x; the floor only catches the mmap path degrading
        # to a re-parse, with headroom for noisy shared CI runners
        assert speedup >= 3.0, (us_cold, us_replay)
        return [
            row("restart.cold_start", us_cold,
                f"{n}records,wal_replayed={wal_lines},"
                f"{speedup:.1f}x_vs_line_replay"),
            row("restart.line_replay", us_replay, f"{n}records"),
        ]
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def bench_compaction(out_dir: Path):
    """Segment compaction + tiered storage (docs/storage.md) on the
    ≥100k-record fleet workload sealed into hundreds of small segments
    (a long-running aggregator's steady state).  Measures the *cold*
    fleet query — fresh read-only open per call, so every manifest and
    payload is re-read from disk — before vs after compaction into
    compressed cold-tier segments, the compressed-vs-raw byte ratio,
    and a rollup-tier aggregate vs the same query forced down the raw
    columnar scan.  Asserts the ISSUE 6 acceptance floors: >= 10x
    segment-count reduction, >= 3x cold-query speedup, identical rows
    pre/post compaction, and rollup aggregates matching the raw scan."""
    import shutil
    import tempfile
    from repro.core.aggregator import MetricStore
    from repro.core.splunklite import query
    tmp = Path(tempfile.mkdtemp())
    try:
        store = MetricStore(seal_threshold=128, directory=tmp / "store")
        _fleet_store(n_jobs=110, hosts_per_job=8, samples=60, store=store)
        store.seal()
        n = len(store)
        segs_before = len(store._sealed)
        bytes_raw = store.storage_stats()["bytes"]
        store.close()
        q = ("search kind=perf gflops>0 "
             "| stats avg(gflops) p90(step_time_s) count by job "
             "| sort -avg_gflops | head 10")

        def cold_query():
            st = MetricStore(seal_threshold=128, directory=tmp / "store",
                             read_only=True)
            try:
                return query(st, q)
            finally:
                st.close()

        want = cold_query()
        us_pre = timeit(cold_query, warmup=1, iters=3)
        rw = MetricStore(seal_threshold=128, directory=tmp / "store")
        cstats = rw.compact()
        segs_after = len(rw._sealed)
        storage = rw.storage_stats()
        cold_tier = storage["tiers"]["cold"]
        rw.close()
        assert cold_query() == want, "rows diverged after compaction"
        us_post = timeit(cold_query, warmup=1, iters=3)
        reduction = segs_before / max(segs_after, 1)
        speedup = us_pre / max(us_post, 1e-9)
        # acceptance floors from ISSUE 6 (measured with headroom)
        assert reduction >= 10.0, (segs_before, segs_after)
        assert speedup >= 3.0, (us_pre, us_post)
        byte_ratio = cold_tier["bytes"] / max(cold_tier["raw_bytes"], 1)
        # rollup tier: bucketed partial-aggregate columns answer the
        # fleet aggregate without touching any raw segment
        ru = MetricStore(seal_threshold=128, directory=tmp / "store")
        ru.apply_retention(rollups=[(60.0, 0.0)])
        rq = "kind=perf ts>=0 | stats avg(gflops) count by job"
        got_ru = {r["job"]: r for r in query(ru, rq)}
        want_ru = {r["job"]: r
                   for r in query(ru, rq, engine="columnar")}
        assert got_ru.keys() == want_ru.keys()
        for job, w in want_ru.items():
            assert got_ru[job]["count"] == w["count"]
            assert abs(got_ru[job]["avg_gflops"] - w["avg_gflops"]) <= 1e-6
        assert ru.last_query_stats["rollup_segments"] > 0
        us_rollup = timeit(lambda: query(ru, rq), warmup=1, iters=5)
        us_raw = timeit(lambda: query(ru, rq, engine="columnar"),
                        warmup=1, iters=5)
        ru.close()
        return [
            row("compaction.cold_query_pre", us_pre,
                f"{n}records,{segs_before}segments,uncompacted"),
            row("compaction.cold_query_post", us_post,
                f"{segs_after}segments,{reduction:.0f}x_fewer,"
                f"{speedup:.1f}x_faster,"
                f"{cstats['rows']}rows_merged"),
            row("compaction.compressed_bytes", cold_tier["bytes"],
                f"{byte_ratio:.2f}x_of_raw,{bytes_raw}raw_bytes"),
            row("compaction.rollup_query", us_rollup,
                f"gran=60s,{us_raw / max(us_rollup, 1e-9):.1f}"
                "x_vs_raw_scan"),
            row("compaction.rollup_query_raw", us_raw,
                "same_run_raw_columnar_scan"),
        ]
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def bench_transport(out_dir: Path):
    """rsyslog-analog throughput: lines/s through spool->ship->ingest."""
    import tempfile
    from repro.core.aggregator import Aggregator
    from repro.core.schema import MetricRecord, encode_line
    from repro.core.transport import Shipper, Spool, StreamFileSink
    tmp = Path(tempfile.mkdtemp())
    sp = Spool(tmp / "spool")
    lines = [encode_line(MetricRecord(1000.0 + i, "n0", "j", "perf",
                                      {"gflops": float(i), "step": i}))
             for i in range(5000)]
    t0 = time.perf_counter()
    for ln in lines:
        sp.write_line(ln)
    agg = Aggregator(tmp / "inbox")
    Shipper(tmp / "spool", StreamFileSink(tmp / "inbox" / "n0.log")
            ).ship_once()
    n = agg.pump()
    dt = time.perf_counter() - t0
    assert n == 5000
    return [row("transport.pipeline", dt / n * 1e6,
                f"{n / dt:.0f}lines_per_s")]
