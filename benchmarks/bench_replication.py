"""Replicated shard fleet: hedged-scatter tail latency vs unhedged
(docs/replication.md).

The paper's monitoring queries are dashboard-interactive: tail latency
is what an operator feels when one indexer of a replicated pair is
slow (GC pause, noisy neighbor, failing disk).  This bench builds a
2-shard fleet with ``replicas=2``, makes one member of one shard
artificially slow via the worker's ``set_delay`` fault-injection knob,
and measures the p99 scatter latency with hedging off vs on.  Hedged
scatters fire a backup request to the other replica after a short
delay and take the first byte-identical reply, so the slow member
stops defining the tail.

Acceptance (asserted here and guarded in CI, normalized by the
same-run unhedged p99 so the bound is machine-independent): hedged p99
<= 0.6x unhedged p99 with one slow worker.
"""

import time
from pathlib import Path

import numpy as np

from benchmarks.common import row

SLOW_S = 0.08        # injected per-scatter delay on one member
HEDGE_S = 0.01       # fixed hedge delay: fire the backup after 10ms
ITERS = 40


def _percentile(lats, p):
    return float(np.percentile(np.asarray(lats, np.float64), p))


def _measure(fleet, q, iters=ITERS):
    from repro.core.splunklite import query
    lats = []
    for _ in range(iters):
        t0 = time.perf_counter()
        query(fleet, q)
        lats.append((time.perf_counter() - t0) * 1e6)
        assert fleet.last_query_stats["degraded_shards"] == 0
    return lats


def bench_replication(out_dir: Path):
    """p99 scatter latency, hedged vs unhedged, one slow member."""
    import shutil
    import tempfile
    from benchmarks.monitoring import _fleet_store
    from repro.core.remote import RemoteShardedAggregator
    from repro.core.splunklite import query
    tmp = Path(tempfile.mkdtemp())
    fleet = None
    try:
        fleet = RemoteShardedAggregator(num_shards=2,
                                        directory=tmp / "fleet",
                                        seal_threshold=4096,
                                        replicas=2,
                                        hedge_delay_s=HEDGE_S,
                                        worker_idle_timeout_s=300.0,
                                        spawn_timeout_s=60.0)
        _fleet_store(n_jobs=40, hosts_per_job=4, samples=30, store=fleet)
        fleet.seal()
        sync = fleet.sync_replicas()
        assert all(s["synced"] == s["replicas"] for s in sync), sync
        n = len(fleet)
        q = ("search kind=perf gflops>0 "
             "| stats avg(gflops) p90(step_time_s) count by job "
             "| sort -avg_gflops | head 10")
        want = query(fleet, q)  # also measures member latencies
        # one member of shard 0 — whichever the coordinator currently
        # prefers, so the slowness lands on the hot read path
        slow = fleet.shards[0]._read_order()[0]
        slow.rpc("set_delay", s=SLOW_S)

        def set_hedging(on: bool) -> None:
            for sh in fleet.shards:
                sh.hedge_enabled = on

        set_hedging(False)
        assert query(fleet, q) == want, "unhedged rows diverged"
        unhedged = _measure(fleet, q)
        set_hedging(True)
        assert query(fleet, q) == want, "hedged rows diverged"
        hedged = _measure(fleet, q)
        p99_unhedged = _percentile(unhedged, 99.0)
        p99_hedged = _percentile(hedged, 99.0)
        ratio = p99_hedged / max(p99_unhedged, 1e-9)
        rs = fleet.replication_stats()
        assert rs["hedged_ops"] > 0 and rs["hedge_wins"] > 0, rs
        # acceptance: with one slow worker, hedging takes the slow
        # member out of the tail — hedged p99 <= 0.6x unhedged p99
        assert ratio <= 0.6, (p99_hedged, p99_unhedged)
        return [
            row("replication.p99_hedged", p99_hedged,
                f"{n}records,2x2workers,{ratio:.2f}x_of_unhedged"),
            row("replication.p99_unhedged", p99_unhedged,
                f"one_member_slowed_{int(SLOW_S * 1e3)}ms"),
            row("replication.p50_hedged", _percentile(hedged, 50.0),
                f"hedge_delay_{int(HEDGE_S * 1e3)}ms"),
        ]
    finally:
        if fleet is not None:
            fleet.close()
        shutil.rmtree(tmp, ignore_errors=True)
