"""Shared benchmark utilities."""

import sys
import time
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

EXPERIMENTS = Path(__file__).resolve().parents[1] / "experiments"


def timeit(fn, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"
