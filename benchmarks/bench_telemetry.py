"""Tracing overhead on the warm fleet query path (docs/observability.md).

Observability that taxes the hot path gets turned off; ISSUE 10's
acceptance bound is that it never has to be.  This bench builds the
same 2-shard worker fleet twice — once with tracing on (every query
grows a full coordinator+worker span tree, adopted over the wire,
plus a SelfMonitor snapshot per iteration) and once with tracing off
(the NULL_SPAN fast path; registry collectors exist but nothing
scrapes them mid-query) — and measures the warm remote fleet query
both ways.

Acceptance (asserted here and guarded in CI via ``check_regression
--max-ratio``, normalized in-run so the bound is machine-independent):
traced warm-query latency <= 1.10x the bare fleet's.
"""

import time
from pathlib import Path

import numpy as np

ITERS = 60
WARMUP = 5
MAX_RATIO = 1.10

Q = ("search kind=perf gflops>0 "
     "| stats avg(gflops) p90(step_time_s) count by job "
     "| sort -avg_gflops | head 10")


def _build_fleet(tmp: Path, traced: bool):
    from benchmarks.monitoring import _fleet_store
    from repro.core.remote import RemoteShardedAggregator
    from repro.core.telemetry import Telemetry
    fleet = RemoteShardedAggregator(num_shards=2, directory=tmp,
                                    seal_threshold=4096,
                                    worker_idle_timeout_s=300.0,
                                    spawn_timeout_s=60.0,
                                    telemetry=Telemetry(tracing=traced))
    _fleet_store(n_jobs=40, hosts_per_job=4, samples=30, store=fleet)
    fleet.seal()
    return fleet


def _measure(fleet, monitor=None) -> list:
    from repro.core.schema import MetricRecord
    from repro.core.splunklite import query
    # a mutation between queries defeats the coordinator's etag memo,
    # so every iteration exercises the full scatter wire path (and,
    # traced, records the full span tree for it)
    lats = []
    for i in range(ITERS + WARMUP):
        fleet.insert(MetricRecord(5e6 + i, "bench-n0", "bench.1", "perf",
                                  {"gflops": float(i)}))
        t0 = time.perf_counter()
        query(fleet, Q)
        lats.append((time.perf_counter() - t0) * 1e6)
        assert fleet.last_query_stats["degraded_shards"] == 0
        if monitor is not None:
            monitor.pump()
    return lats[WARMUP:]


def bench_telemetry(out_dir: Path):
    """Warm remote fleet query: tracing + self-ingestion vs off."""
    import shutil
    import tempfile
    from benchmarks.common import row
    from repro.core.aggregator import MetricStore
    from repro.core.splunklite import query
    from repro.core.telemetry import SelfMonitor
    tmp = Path(tempfile.mkdtemp())
    rows = []
    try:
        results = {}
        want = None
        for label, traced in (("bare", False), ("traced", True)):
            fleet = _build_fleet(tmp / label, traced)
            try:
                got = query(fleet, Q)
                if want is None:
                    want = got
                else:
                    assert got == want, "traced rows diverged from bare"
                monitor = (SelfMonitor(fleet.telemetry, MetricStore(),
                                       interval_s=0.0) if traced else None)
                results[label] = float(np.median(_measure(fleet, monitor)))
                if traced:
                    tid, spans = fleet.telemetry.tracer.last_trace()
                    assert tid is not None and len(spans) >= 5, \
                        "tracing was supposed to be on"
                    assert any(s["node"].startswith("worker:")
                               for s in spans), "worker spans not adopted"
                    assert len(query(monitor.sink,
                                     "search kind=fleet")) == ITERS + WARMUP
                else:
                    assert fleet.telemetry.tracer.last_trace() == (None, [])
            finally:
                fleet.close()
        ratio = results["traced"] / max(results["bare"], 1e-9)
        # acceptance: spans + wire adoption + self-ingestion cost <= 10%
        # on the warm query path
        assert ratio <= MAX_RATIO, (results, ratio)
        rows.append(row("telemetry.fleet_query_traced", results["traced"],
                        f"2workers,{ratio:.3f}x_of_bare"))
        rows.append(row("telemetry.fleet_query_bare", results["bare"],
                        "tracing_off_null_spans"))
        return rows
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
