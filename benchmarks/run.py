"""Benchmark driver — one benchmark per paper table/figure/claim.

Prints ``name,us_per_call,derived`` CSV rows (stdout), writes rendered
dashboards under experiments/dashboards/, and emits machine-readable
results to ``experiments/BENCH_splunklite.json`` so the performance
trajectory is tracked across PRs.

  data_volume   — paper §5 log-volume table
  overhead      — paper §4 negligible-overhead claim
  roofline_view — paper Fig. 2
  job_view      — paper Fig. 3
  detectors     — paper §4.4 specialized views / §5 case studies
  splunklite    — analysis-layer query latency (columnar vs legacy rows)
  sharded       — multi-aggregator scatter/gather fan-out vs single store
  incremental   — segment-keyed partial-aggregate cache: cold vs warm
  remote        — worker-process shard fleet vs in-process sharded
  replication   — replicated shards: hedged-scatter p99 vs unhedged
                  with one artificially slow member
  faults        — fault-tolerance overhead: hardened warm fleet query
                  vs checksums/retry/breakers all off (<= 1.15x)
  telemetry     — tracing + self-ingestion overhead: traced warm fleet
                  query vs tracing off (<= 1.10x)
  compaction    — segment compaction + compressed tiers: cold query
                  pre/post, byte ratio, rollup vs raw scan
  restart       — aggregator cold-start: mmap segments vs line replay
  transport     — rsyslog-analog throughput
  kernels.*     — Pallas kernels vs jnp oracles (interpret mode)
"""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import EXPERIMENTS  # noqa: E402


def _parse_row(line: str):
    name, us, derived = line.split(",", 2)
    try:
        us_val = float(us)
    except ValueError:
        us_val = None
    return {"name": name, "us_per_call": us_val, "derived": derived}


def main() -> None:
    from benchmarks import kernels as kbench
    from benchmarks import monitoring as mbench
    from benchmarks.bench_faults import bench_faults
    from benchmarks.bench_replication import bench_replication
    from benchmarks.bench_telemetry import bench_telemetry
    only = set(sys.argv[1:])
    out = EXPERIMENTS
    out.mkdir(parents=True, exist_ok=True)
    benches = [
        mbench.bench_data_volume,
        mbench.bench_overhead,
        mbench.bench_roofline_view,
        mbench.bench_job_view,
        mbench.bench_detectors,
        mbench.bench_anomaly,
        mbench.bench_splunklite,
        mbench.bench_sharded,
        mbench.bench_incremental,
        mbench.bench_remote,
        bench_replication,
        bench_faults,
        bench_telemetry,
        mbench.bench_service,
        mbench.bench_compaction,
        mbench.bench_restart,
        mbench.bench_transport,
        kbench.bench_flash_attention,
        kbench.bench_ssd,
        kbench.bench_xla_attention_paths,
    ]
    if only:
        benches = [b for b in benches
                   if b.__name__.replace("bench_", "") in only]
    print("name,us_per_call,derived")
    results = []
    failures = 0
    for bench in benches:
        try:
            for line in bench(out):
                print(line, flush=True)
                results.append(_parse_row(line))
        except Exception as exc:  # noqa: BLE001
            failures += 1
            line = f"{bench.__name__},ERROR,{type(exc).__name__}: {exc}"
            print(line, flush=True)
            results.append(_parse_row(line))
    # merge into the tracked results file by row name so filtered runs
    # (e.g. `run.py splunklite`) update their rows without clobbering
    # the rest of the trajectory
    bench_path = out / "BENCH_splunklite.json"
    merged = {}
    try:
        for r in json.loads(bench_path.read_text()).get("rows", []):
            merged[r["name"]] = r
    except (OSError, ValueError, KeyError):
        pass
    # a bench that ran again supersedes its previous ERROR row (error
    # rows are keyed by the bench function name)
    for bench in benches:
        merged.pop(bench.__name__, None)
    for r in results:
        merged[r["name"]] = r
    stale_failures = sum(1 for r in merged.values()
                         if r["us_per_call"] is None)
    bench_path.write_text(json.dumps(
        {"rows": list(merged.values()), "failures": stale_failures},
        indent=2) + "\n")
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    main()
