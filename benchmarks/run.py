"""Benchmark driver — one benchmark per paper table/figure/claim.

Prints ``name,us_per_call,derived`` CSV rows (stdout) and writes rendered
dashboards under experiments/dashboards/.

  data_volume   — paper §5 log-volume table
  overhead      — paper §4 negligible-overhead claim
  roofline_view — paper Fig. 2
  job_view      — paper Fig. 3
  detectors     — paper §4.4 specialized views / §5 case studies
  splunklite    — analysis-layer query latency
  transport     — rsyslog-analog throughput
  kernels.*     — Pallas kernels vs jnp oracles (interpret mode)
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import EXPERIMENTS  # noqa: E402


def main() -> None:
    from benchmarks import kernels as kbench
    from benchmarks import monitoring as mbench
    out = EXPERIMENTS
    out.mkdir(parents=True, exist_ok=True)
    benches = [
        mbench.bench_data_volume,
        mbench.bench_overhead,
        mbench.bench_roofline_view,
        mbench.bench_job_view,
        mbench.bench_detectors,
        mbench.bench_anomaly,
        mbench.bench_splunklite,
        mbench.bench_transport,
        kbench.bench_flash_attention,
        kbench.bench_ssd,
        kbench.bench_xla_attention_paths,
    ]
    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        try:
            for line in bench(out):
                print(line, flush=True)
        except Exception as exc:  # noqa: BLE001
            failures += 1
            print(f"{bench.__name__},ERROR,{type(exc).__name__}: {exc}",
                  flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    main()
