"""Fault-tolerance overhead on the fault-free hot path (docs/faults.md).

The hardening ISSUE 9 adds — crc32c trailers on every wire frame,
per-line WAL checksums, retry bookkeeping, idempotency keys on
mutations, per-worker circuit breakers — must cost (nearly) nothing
when nothing is failing: the monitoring fleet spends its life on the
fault-free path.  This bench builds the same replicated 2x2 fleet
twice — once hardened (the defaults) and once with every robustness
feature off (no frame checksums either direction, no retry policy, no
breakers) — and measures the warm remote fleet query both ways.

Acceptance (asserted here and guarded in CI via ``check_regression
--max-ratio``, normalized in-run so the bound is machine-independent):
hardened warm-query latency <= 1.15x the bare fleet's.
"""

import time
from pathlib import Path

import numpy as np

ITERS = 60
WARMUP = 5
MAX_RATIO = 1.15

Q = ("search kind=perf gflops>0 "
     "| stats avg(gflops) p90(step_time_s) count by job "
     "| sort -avg_gflops | head 10")


def _build_fleet(tmp: Path, hardened: bool):
    from benchmarks.monitoring import _fleet_store
    from repro.core.remote import RemoteShardedAggregator
    kw = {} if hardened else dict(frame_checksums=False, retry=None,
                                  breaker_threshold=0)
    fleet = RemoteShardedAggregator(num_shards=2, directory=tmp,
                                    seal_threshold=4096, replicas=2,
                                    worker_idle_timeout_s=300.0,
                                    spawn_timeout_s=60.0, **kw)
    if not hardened:
        # the aggregator flag covers coordinator->worker frames; turn
        # off the workers' reply trailers too so the bare fleet pays
        # for no checksum in either direction
        for sh in fleet.shards:
            for m in (sh.members if getattr(sh, "is_replicated", False)
                      else [sh]):
                m.rpc("set_faults", frame_checksums=False)
    _fleet_store(n_jobs=40, hosts_per_job=4, samples=30, store=fleet)
    fleet.seal()
    fleet.sync_replicas()
    return fleet


def _measure(fleet) -> list:
    from repro.core.splunklite import query
    # a mutation between queries defeats the coordinator's etag memo,
    # so every iteration exercises the full scatter wire path (plan
    # out, worker-side warm partial cache, partial maps back)
    from repro.core.schema import MetricRecord
    lats = []
    for i in range(ITERS + WARMUP):
        fleet.insert(MetricRecord(5e6 + i, "bench-n0", "bench.1", "perf",
                                  {"gflops": float(i)}))
        t0 = time.perf_counter()
        query(fleet, Q)
        lats.append((time.perf_counter() - t0) * 1e6)
        assert fleet.last_query_stats["degraded_shards"] == 0
    return lats[WARMUP:]


def bench_faults(out_dir: Path):
    """Warm remote fleet query: hardened vs all robustness off."""
    import shutil
    import tempfile
    from benchmarks.common import row
    from repro.core.splunklite import query
    tmp = Path(tempfile.mkdtemp())
    rows = []
    try:
        results = {}
        want = None
        for label, hardened in (("bare", False), ("hardened", True)):
            fleet = _build_fleet(tmp / label, hardened)
            try:
                got = query(fleet, Q)
                if want is None:
                    want = got
                else:
                    assert got == want, "hardened rows diverged from bare"
                results[label] = float(np.median(_measure(fleet)))
                if hardened:
                    rob = fleet.robustness_stats()
                    assert rob["retries"] == 0, rob  # fault-free run
                    assert rob["opens"] == 0, rob
            finally:
                fleet.close()
        ratio = results["hardened"] / max(results["bare"], 1e-9)
        # acceptance: checksums + retry/idempotency/breaker bookkeeping
        # cost <= 15% on the fault-free warm query path
        assert ratio <= MAX_RATIO, (results, ratio)
        rows.append(row("faults.fleet_query_hardened", results["hardened"],
                        f"2x2workers,{ratio:.3f}x_of_bare"))
        rows.append(row("faults.fleet_query_bare", results["bare"],
                        "checksums_retry_breakers_off"))
        return rows
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
