"""Kernel benchmarks: Pallas (interpret-mode, correctness-representative)
vs pure-jnp oracle, plus the XLA-path attention.  On this CPU container
interpret-mode timings measure the *interpreter*, not the TPU — the CSV's
value is the allclose check + the roofline-relevant shapes; real timing
happens on hardware.
"""

from __future__ import annotations

from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit


def bench_flash_attention(out_dir: Path):
    from repro.kernels.ops import flash_attention_op
    from repro.kernels.ref import ref_attention
    B, S, HQ, HKV, D = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, HQ, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, HKV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, HKV, D), jnp.float32)

    def pallas():
        return flash_attention_op(q, k, v, causal=True, window=64,
                                  softcap=50.0, block_q=64, block_k=64,
                                  interpret=True).block_until_ready()

    def ref():
        return ref_attention(q, k, v, causal=True, window=64,
                             softcap=50.0).block_until_ready()

    err = float(jnp.max(jnp.abs(pallas() - ref())))
    return [
        row("kernels.flash_attention.pallas_interp", timeit(pallas),
            f"err_vs_ref={err:.1e}"),
        row("kernels.flash_attention.jnp_ref", timeit(ref),
            f"B{B}S{S}H{HQ}D{D}"),
    ]


def bench_ssd(out_dir: Path):
    from repro.kernels.ops import ssd_op
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N, Q = 1, 256, 4, 32, 64, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, H))
    bm = jax.random.normal(ks[2], (B, S, N)) * 0.3
    cm = jax.random.normal(ks[3], (B, S, N)) * 0.3

    def pallas():
        y, h = ssd_op(x, dt, a_log, bm, cm, chunk=Q, interpret=True)
        return y.block_until_ready()

    def ref():
        y, h = jax.jit(ssd_chunked, static_argnums=5)(
            x, dt, a_log, bm, cm, Q)
        return y.block_until_ready()

    err = float(jnp.max(jnp.abs(pallas() - ref())))
    return [
        row("kernels.ssd.pallas_interp", timeit(pallas),
            f"err_vs_ref={err:.1e}"),
        row("kernels.ssd.jnp_ref", timeit(ref), f"B{B}S{S}H{H}N{N}"),
    ]


def bench_xla_attention_paths(out_dir: Path):
    """Direct vs chunked(flash-vjp) XLA attention — the fallback the
    dry-run prices."""
    from repro.models.attention import attend
    B, S, HQ, HKV, D = 2, 512, 8, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, HQ, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, HKV, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, HKV, D), jnp.bfloat16)
    pos = jnp.arange(S)

    direct = jax.jit(lambda q, k, v: attend(q, k, v, pos, pos,
                                            causal=True, chunk=0))
    chunked = jax.jit(lambda q, k, v: attend(q, k, v, pos, pos,
                                             causal=True, chunk=128))
    d_us = timeit(lambda: direct(q, k, v).block_until_ready())
    c_us = timeit(lambda: chunked(q, k, v).block_until_ready())
    err = float(jnp.max(jnp.abs(direct(q, k, v).astype(jnp.float32)
                                - chunked(q, k, v).astype(jnp.float32))))
    return [
        row("attention.direct_xla", d_us, f"S{S}"),
        row("attention.chunked_flashvjp_xla", c_us, f"err={err:.1e}"),
    ]
